"""The checked-in generated kernels (transcompiler artifacts) must agree
with their references across a shape sweep — per-kernel allclose vs the
pure-jnp/numpy oracle."""
import numpy as np
import pytest

from repro.kernels import generated as G
from repro.bench.mhc import mhc_post_ref, mhc_post_grad_ref


def _rms_ref(x, w, eps=1e-6):
    x64 = np.asarray(x, np.float64)
    return x64 / np.sqrt((x64 * x64).mean(-1, keepdims=True) + eps) \
        * np.asarray(w, np.float64)


# Checked-in artifacts are shape-specialized like the paper's kernels:
# the trailing dim is baked (make() guards it); rows sweep within the
# generated block size.  Other shapes regenerate through the planner
# (covered by test_regeneration_for_new_shapes).
@pytest.mark.parametrize("rows", [64, 128, 256])
def test_generated_rmsnorm(rows):
    rng = np.random.RandomState(0)
    x = rng.randn(rows, 2048).astype(np.float32)     # bench trailing dim
    w = rng.randn(2048).astype(np.float32)
    out = np.asarray(G.rmsnorm.rmsnorm(x, w, interpret=True))
    np.testing.assert_allclose(out, _rms_ref(x, w), rtol=2e-4, atol=1e-5)


@pytest.mark.parametrize("rows", [16, 32, 64])
def test_generated_softmax(rows):
    rng = np.random.RandomState(1)
    x = rng.randn(rows, 8192).astype(np.float32)     # bench trailing dim
    out = np.asarray(G.softmax.softmax(x, interpret=True))
    e = np.exp(x - x.max(-1, keepdims=True))
    np.testing.assert_allclose(out, e / e.sum(-1, keepdims=True),
                               rtol=2e-4, atol=1e-6)


def test_artifact_guard_and_regeneration_for_new_shapes():
    """Off-spec shapes: the artifact refuses loudly; the planner regenerates
    a correct kernel for the new shape (the paper's workflow)."""
    rng = np.random.RandomState(2)
    x = rng.randn(48, 384).astype(np.float32)
    with pytest.raises(ValueError, match="regenerate"):
        G.softmax.make({"input": x.shape, "output": x.shape},
                       interpret=True)
    from repro.core.planner import PLANNER_REGISTRY
    from repro.core.lowering.pipeline import transcompile, Knobs
    from repro.core.task import KernelTask, TensorSpec
    from repro.core.dsl.ast import DType
    task = KernelTask(
        name="softmax", category="normalization", op="softmax",
        tensors=[TensorSpec("input", DType.f32, "in", 2),
                 TensorSpec("output", DType.f32, "out", 2)],
        shapes={"input": x.shape, "output": x.shape},
        check_shapes={"input": x.shape, "output": x.shape},
        ref=None, attrs={"pad_value": -3.0e38})
    art = transcompile(PLANNER_REGISTRY["softmax"](task, task.shapes,
                                                   Knobs()))
    out = np.asarray(art.entry(x, interpret=True))
    e = np.exp(x - x.max(-1, keepdims=True))
    np.testing.assert_allclose(out, e / e.sum(-1, keepdims=True),
                               rtol=2e-4, atol=1e-6)


@pytest.mark.parametrize("numel", [8192, 24576])
def test_generated_adamw(numel):
    rng = np.random.RandomState(2)
    p = rng.randn(numel).astype(np.float32)
    g = rng.randn(numel).astype(np.float32)
    m = rng.randn(numel).astype(np.float32) * 0.1
    v = rng.uniform(0, 0.1, numel).astype(np.float32)
    np_, nm, nv = G.adamw.adamw(p, g, m, v, interpret=True)
    lr, b1, b2, eps, step, wd = 1e-3, 0.9, 0.999, 1e-8, 10, 0.01
    m64 = b1 * m.astype(np.float64) + (1 - b1) * g
    v64 = b2 * v.astype(np.float64) + (1 - b2) * g.astype(np.float64) ** 2
    up = lr * (m64 / (1 - b1 ** step)) / (np.sqrt(v64 / (1 - b2 ** step))
                                          + eps) + lr * wd * p
    np.testing.assert_allclose(np.asarray(np_), p - up, rtol=1e-4,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(nm), m64, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(nv), v64, rtol=1e-4, atol=1e-6)


def test_generated_swiglu():
    rng = np.random.RandomState(3)
    g = rng.randn(32, 384).astype(np.float32)
    u = rng.randn(32, 384).astype(np.float32)
    out = np.asarray(G.swiglu.swiglu(g, u, interpret=True))
    want = g / (1 + np.exp(-g.astype(np.float64))) * u
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=1e-6)


def test_generated_mhc_post():
    rng = np.random.RandomState(4)
    R, n, d = 64, 4, 256
    h = rng.randn(R, n, d).astype(np.float32)
    o = rng.randn(R, d).astype(np.float32)
    logits = rng.randn(n, n).astype(np.float32) * 0.3
    beta = rng.rand(n).astype(np.float32)
    out = np.asarray(G.mhc_post.mhc_post(h, o, logits, beta,
                                         interpret=True))
    np.testing.assert_allclose(out, mhc_post_ref(h, o, logits, beta),
                               rtol=2e-4, atol=1e-5)


def test_generated_mhc_post_grad():
    rng = np.random.RandomState(5)
    R, n, d = 64, 4, 256
    g = rng.randn(R, n, d).astype(np.float32)
    logits = rng.randn(n, n).astype(np.float32) * 0.3
    beta = rng.rand(n).astype(np.float32)
    dh, do = G.mhc_post_grad.mhc_post_grad(g, logits, beta, interpret=True)
    rdh, rdo = mhc_post_grad_ref(g, logits, beta)
    np.testing.assert_allclose(np.asarray(dh), rdh, rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(do), rdo, rtol=2e-4, atol=1e-5)


def test_artifacts_carry_provenance_headers():
    import inspect
    for mod in (G.rmsnorm, G.softmax, G.adamw, G.swiglu, G.mhc_post):
        src = inspect.getsource(mod)
        assert "generated by repro.core" in src
        assert "pass0/validate" in src          # pass log embedded


@pytest.mark.parametrize("rows", [64, 128])
def test_generated_add_rmsnorm(rows):
    rng = np.random.RandomState(7)
    x = rng.randn(rows, 2048).astype(np.float32)
    r = rng.randn(rows, 2048).astype(np.float32)
    w = rng.randn(2048).astype(np.float32)
    y, new_res = G.add_rmsnorm.add_rmsnorm(x, r, w, interpret=True)
    s = x.astype(np.float64) + r.astype(np.float64)
    want = s / np.sqrt((s * s).mean(-1, keepdims=True) + 1e-6) * w
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(new_res), s, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("rows", [64, 128])
def test_generated_fused_bias_gelu(rows):
    """Checked-in fused-chain artifact (DESIGN.md §9): one UB visit, the
    tuner-selected variant."""
    import math
    rng = np.random.RandomState(9)
    x = rng.randn(rows, 4096).astype(np.float32)
    b = rng.randn(4096).astype(np.float32)
    y = G.bias_gelu.bias_gelu_fused(x, b, interpret=True)
    s = x.astype(np.float64) + b.astype(np.float64)
    want = 0.5 * s * (1 + np.vectorize(math.erf)(s / math.sqrt(2)))
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-4, atol=1e-5)
    src = __import__("inspect").getsource(G.bias_gelu)
    assert "Store/Load round trips deleted" in src


def test_generated_fused_rmsnorm_swiglu():
    rng = np.random.RandomState(11)
    x = rng.randn(64, 4096).astype(np.float32)
    w = rng.randn(4096).astype(np.float32)
    g = rng.randn(64, 4096).astype(np.float32)
    y = G.rmsnorm_swiglu.rmsnorm_swiglu_fused(x, w, g, interpret=True)
    x64, w64, g64 = (np.asarray(v, np.float64) for v in (x, w, g))
    h = x64 / np.sqrt((x64 * x64).mean(-1, keepdims=True) + 1e-6) * w64
    want = h / (1 + np.exp(-h)) * g64
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-4, atol=1e-5)


@pytest.mark.parametrize("rows", [64, 128])
def test_generated_fused_swiglu_proj(rows):
    """Checked-in DAG-chain artifact (DESIGN.md §10): the tuner-selected
    fused two-branch swiglu loads the shared input once."""
    rng = np.random.RandomState(13)
    x = rng.randn(rows, 4096).astype(np.float32)
    gs = rng.randn(4096).astype(np.float32)
    us = rng.randn(4096).astype(np.float32)
    y = G.swiglu_proj.swiglu_proj_fused(x, gs, us, interpret=True)
    x64 = np.asarray(x, np.float64)
    g = x64 * np.asarray(gs, np.float64)
    u = x64 * np.asarray(us, np.float64)
    want = g / (1 + np.exp(-g)) * u
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-4, atol=1e-5)


@pytest.mark.parametrize("rows", [32, 64])
def test_generated_fused_mask_softmax(rows):
    """Checked-in artifact of the jaxpr-EXTRACTED chain (DESIGN.md §11):
    additively-masked softmax discovered inside the flash-attention
    reference — the tuner-selected fused resident form."""
    rng = np.random.RandomState(17)
    x = rng.randn(rows, 8192).astype(np.float32)
    m = np.where(rng.rand(rows, 8192) > 0.25, 0.0, -1.0e9) \
        .astype(np.float32)
    y = G.mask_softmax.mask_softmax_fused(x, m, interpret=True)
    s = x.astype(np.float64) + m.astype(np.float64)
    e = np.exp(s - s.max(-1, keepdims=True))
    want = e / e.sum(-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-4, atol=1e-6)
    src = __import__("inspect").getsource(G.mask_softmax)
    assert "Store/Load round trips deleted" in src


def test_generated_attn_scores_is_streaming_and_guarded():
    """The attn_scores artifact is the loop-carry-stitched STREAMING chain
    (rows far too wide for residency): running scalars + the one-time
    score spill are visible in the emitted source, and make() refuses
    shapes it was not specialized for.  (Numerics are covered at check
    shapes by tests/core/test_fusion.py — executing the 786k-wide bench
    shape in interpret mode is not test-budget material.)"""
    import inspect
    src = inspect.getsource(G.attn_scores)
    assert "running scalars loop-carried" in src
    assert "backend  : explicit" in src
    with pytest.raises(ValueError, match="trailing dimension"):
        G.attn_scores.make({"input": (32, 512), "scale": (512,),
                            "mask": (512,), "output": (32, 512)})


def test_generated_double_softmax_is_multi_stat_streaming():
    """The double_softmax artifact is the MULTI-STAT streaming chain
    (DESIGN.md §12): two independent online (m, d) recurrences visible in
    the emitted source — the second stat's first pass jammed into the
    first stat's output pass, the inter-stat link spilled once — and
    make() refuses shapes it was not specialized for.  (Numerics are
    covered at check shapes by tests/core/test_fusion.py.)"""
    import inspect
    src = inspect.getsource(G.double_softmax)
    assert "running scalars loop-carried" in src
    assert "backend  : explicit" in src
    # both stats' running denominators survived stitching
    assert "f0_row_den" in src and "f1_row_den" in src
    # the per-stat spill pad blend (iota/mask/where) is in the kernel
    assert "f0_padmsk" in src
    with pytest.raises(ValueError, match="trailing dimension"):
        G.double_softmax.make({"input": (32, 512), "output": (32, 512)})
    # streaming artifacts bake per-core row loop trip counts: a different
    # row count must refuse, not silently compute garbage
    with pytest.raises(ValueError, match="row count"):
        G.double_softmax.make({"input": (512, 786432),
                               "output": (512, 786432)})


# ---------------- backward-chain artifacts (DESIGN.md §16) ----------------
# Checked-in artifacts of the jaxpr-EXTRACTED VJP chains — each backward
# legality class gets one standalone kernel, verified against the
# transposed-jaxpr composite in float64.

@pytest.mark.parametrize("rows", [64, 128])
def test_generated_attn_scores_bwd(rows):
    """softmax VJP behind a rematerialized mask-add: y*(g - sum(g*y))."""
    rng = np.random.RandomState(19)
    z = rng.randn(rows, 8192).astype(np.float32)
    m = np.where(rng.rand(rows, 8192) > 0.25, 0.0, -1.0e9) \
        .astype(np.float32)
    g = rng.randn(rows, 8192).astype(np.float32)
    out = G.attn_scores_bwd.attn_scores_bwd_fused(z, m, g, interpret=True)
    s = z.astype(np.float64) + m.astype(np.float64)
    e = np.exp(s - s.max(-1, keepdims=True))
    y = e / e.sum(-1, keepdims=True)
    g64 = g.astype(np.float64)
    want = y * (g64 - (g64 * y).sum(-1, keepdims=True))
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=1e-5)


@pytest.mark.parametrize("rows", [64, 128])
def test_generated_lm_head_bwd(rows):
    """log_softmax VJP behind the bias-add: g - softmax(z+b)*sum(g)."""
    rng = np.random.RandomState(21)
    z = rng.randn(rows, 8192).astype(np.float32)
    b = rng.randn(8192).astype(np.float32)
    g = rng.randn(rows, 8192).astype(np.float32)
    out = G.lm_head_bwd.lm_head_bwd_fused(z, b, g, interpret=True)
    s = z.astype(np.float64) + b.astype(np.float64)
    e = np.exp(s - s.max(-1, keepdims=True))
    y = e / e.sum(-1, keepdims=True)
    g64 = g.astype(np.float64)
    want = g64 - y * g64.sum(-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=1e-5)


@pytest.mark.parametrize("rows", [64, 128])
def test_generated_norm_residual_bwd(rows):
    """rmsnorm input-VJP plus the residual skip's pass-through grad."""
    rng = np.random.RandomState(23)
    x = rng.randn(rows, 2048).astype(np.float32)
    w = rng.randn(2048).astype(np.float32)
    g = rng.randn(rows, 2048).astype(np.float32)
    out = G.norm_residual_bwd.norm_residual_bwd_fused(x, w, g,
                                                      interpret=True)
    x64, g64 = x.astype(np.float64), g.astype(np.float64)
    n = g64 * w.astype(np.float64)
    inv = 1.0 / np.sqrt((x64 * x64).mean(-1, keepdims=True) + 1e-6)
    s = (x64 * n).sum(-1, keepdims=True)
    want = g64 + n * inv - x64 * s * inv ** 3 / x64.shape[-1]
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=1e-5)


def test_generated_ce_grad():
    """Cross-entropy grad epilogue: (probs - onehot, onehot*logp)."""
    rng = np.random.RandomState(25)
    oh = (rng.rand(64, 4096) < (1.0 / 4096)).astype(np.float32)
    lg = rng.randn(64, 4096).astype(np.float32)
    x2 = rng.randn(64, 4096).astype(np.float32)
    dout, loss_term = G.ce_grad.ce_grad_fused(oh, lg, x2, interpret=True)
    np.testing.assert_allclose(
        np.asarray(dout), x2.astype(np.float64) - oh, rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(loss_term), oh.astype(np.float64) * lg,
        rtol=2e-4, atol=1e-5)


def test_generated_mhc_stream_bwd():
    """The mhc_post_grad source chain: 4-way scalar-weighted grad sum with
    dynamic 1-element mix weights (smul via extract_scalar)."""
    rng = np.random.RandomState(27)
    mats = [rng.randn(64, 4096).astype(np.float32) for _ in range(4)]
    scals = [rng.randn(1).astype(np.float32) for _ in range(4)]
    out = G.mhc_stream_bwd_c0.mhc_stream_bwd_c0_fused(
        mats[0], scals[0], mats[1], scals[1], mats[2], scals[2],
        mats[3], scals[3], interpret=True)
    want = sum(m.astype(np.float64) * float(s[0])
               for m, s in zip(mats, scals))
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=1e-5)
    src = __import__("inspect").getsource(G.mhc_stream_bwd_c0)
    assert "Store/Load round trips deleted" in src


def test_generated_mlp_bwd_chains():
    """Both SwiGLU backward clusters: the sigmoid-reuse DAG (4 outputs)
    and the up-branch epilogue."""
    rng = np.random.RandomState(29)
    x, x1, x2, x3 = (rng.randn(64, 4096).astype(np.float32)
                     for _ in range(4))
    h1, h4, h5, out = G.mlp_bwd_c0.mlp_bwd_c0_fused(x, x1, x2,
                                                    interpret=True)
    x64 = x.astype(np.float64)
    sg = 1.0 / (1.0 + np.exp(-x64))
    h2 = x1.astype(np.float64) * x2.astype(np.float64)
    np.testing.assert_allclose(np.asarray(h1), sg, rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h4), x64 * h2,
                               rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h5), h2 * sg,
                               rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out), (x64 * sg)
                               * x1.astype(np.float64),
                               rtol=2e-4, atol=1e-5)
    y = G.mlp_bwd_c1.mlp_bwd_c1_fused(x, x1, x2, x3, interpret=True)
    want = x2.astype(np.float64) * (x64 * x1.astype(np.float64)) \
        + x3.astype(np.float64)
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-4, atol=1e-5)


# ---------------- quantized-storage artifacts (DESIGN.md §17) --------------
# Checked-in artifacts of the tuner-DISCOVERED int8-storage fused chains —
# the storage axis is open on their tasks (attrs['tuner_axes']), never
# hand-pinned, so regeneration re-finds (fused, int8) by search.

def test_generated_rmsnorm_swiglu_int8():
    """The resident quantized chain: f32-in/f32-out entry contract (the
    wrapper quantizes narrow GM tensors itself), dequant fused into the
    first compute pass, output within the documented int8 tolerance."""
    from repro.core.fusion.chain import Q_VERIFY_TOL
    rng = np.random.RandomState(17)
    x = rng.randn(64, 4096).astype(np.float32)
    w = rng.uniform(0.5, 1.5, 4096).astype(np.float32)
    g = rng.randn(64, 4096).astype(np.float32)
    y = G.rmsnorm_swiglu_int8.rmsnorm_swiglu_int8_fused(x, w, g,
                                                        interpret=True)
    x64, w64, g64 = (np.asarray(v, np.float64) for v in (x, w, g))
    h = x64 / np.sqrt((x64 * x64).mean(-1, keepdims=True) + 1e-6) * w64
    want = h / (1 + np.exp(-h)) * g64
    rtol, atol = Q_VERIFY_TOL["int8"]
    assert np.allclose(np.asarray(y), want, rtol=rtol, atol=atol), \
        f"max abs err {np.max(np.abs(np.asarray(y) - want)):.4g}"
    src = __import__("inspect").getsource(G.rmsnorm_swiglu_int8)
    # the quantize glue and narrow GM storage are visible in the source
    assert "astype(jnp.int8)" in src
    assert "storage_dtype=int8" in src or "int8" in src


def test_generated_attn_scores_int8_is_streaming_and_quantized():
    """The streaming quantized chain: loop-carry stitching survived the
    quant rewrite (running scalars visible), narrow GM params + the
    round-half-up quantizer are in the emitted source, and make()
    refuses foreign shapes.  (Numerics are covered at check shapes by
    the quantized differential rows in tests/core/test_fusion.py —
    the 786k-wide bench shape is not test-budget material.)"""
    import inspect
    src = inspect.getsource(G.attn_scores_int8)
    assert "running scalars loop-carried" in src
    assert "astype(jnp.int8)" in src
    assert "jnp.floor" in src and "jnp.clip" in src   # round-half-up glue
    with pytest.raises(ValueError, match="trailing dimension"):
        G.attn_scores_int8.make({"input": (32, 512), "scale": (512,),
                                 "mask": (512,), "output": (32, 512)})
