"""Explicit-DMA double-buffered kernel (Ascend MTE/TQue analogue) vs oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dma_pipeline import scale_bias_gelu, scale_bias_gelu_ref


@pytest.mark.parametrize("numel,tile,cores", [
    (8 * 512, 512, 8),            # n_tiles = 1 (epilogue-only path)
    (8 * 512 * 2, 512, 8),        # n_tiles = 2 (double-buffer handoff)
    (8 * 512 * 5, 512, 8),        # odd tile count (slot rotation)
    (4 * 256 * 8, 256, 4),
])
def test_dma_pipeline_matches_ref(numel, tile, cores):
    x = jnp.asarray(np.random.RandomState(0).randn(numel), jnp.float32)
    out = scale_bias_gelu(x.reshape(-1), scale=1.3, bias=-0.2,
                          interpret=True)
    # rebuild with explicit params
    from repro.kernels.dma_pipeline.kernel import dma_scale_bias_gelu
    out = dma_scale_bias_gelu(x, scale=1.3, bias=-0.2, n_cores=cores,
                              tile=tile, interpret=True)
    ref = scale_bias_gelu_ref(x, 1.3, -0.2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-6, atol=2e-6)
