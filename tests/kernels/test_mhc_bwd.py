"""Golden re-derivation of mhc_post_grad (DESIGN.md §16): the assembly
built from the TRACED-VJP extracted chain must match the hand-written
generated kernel and the float64 oracle, and the chain's provenance must
record extraction."""
import numpy as np
import pytest

from repro.bench.mhc import mhc_post_grad_ref
from repro.kernels import generated as G
from repro.kernels.mhc_bwd import MHC_BWD_CHAIN, mhc_post_grad_derived


def _case(rows, d, seed):
    rng = np.random.RandomState(seed)
    return (rng.randn(rows, 4, d).astype(np.float32),
            rng.randn(4, 4).astype(np.float32),
            rng.randn(4).astype(np.float32))


def test_mhc_bwd_chain_is_extraction_derived():
    """The mixing chain exists, came from the traced mhc_stream_bwd VJP
    workload, and has the expected smul/add-tree structure (all five
    cotangent trees — 4 dh streams + do — fingerprint-deduped onto it)."""
    from repro.core.fusion import CHAINS
    from repro.core.fusion.chain import CHAIN_SOURCES
    assert MHC_BWD_CHAIN in CHAINS
    assert "extracted" in CHAIN_SOURCES[MHC_BWD_CHAIN]
    spec = CHAINS[MHC_BWD_CHAIN]
    ops = [st.op for st in spec.stages]
    assert ops == ["smul"] * 4 + ["add"] * 3
    # 4 stream slices + 4 dynamic scalars, one mixed output
    assert sorted(r for _, r in spec.inputs) == [0, 0, 0, 0, 2, 2, 2, 2]
    assert spec.outputs == ("output",)


@pytest.mark.parametrize("rows,d", [(64, 256), (33, 96)])
def test_derived_matches_f64_oracle(rows, d):
    g, logits, beta = _case(rows, d, seed=rows)
    dh, do = mhc_post_grad_derived(g, logits, beta)
    rdh, rdo = mhc_post_grad_ref(g, logits, beta)
    np.testing.assert_allclose(np.asarray(dh), rdh, rtol=3e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(do), rdo, rtol=3e-4, atol=2e-5)


def test_derived_matches_hand_written_generated_kernel():
    """The golden test: re-derivation ≡ the checked-in hand-written
    artifact at its check geometry."""
    g, logits, beta = _case(64, 256, seed=7)
    dh, do = mhc_post_grad_derived(g, logits, beta)
    hdh, hdo = G.mhc_post_grad.mhc_post_grad(g, logits, beta,
                                             interpret=True)
    np.testing.assert_allclose(np.asarray(dh), np.asarray(hdh),
                               rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(np.asarray(do), np.asarray(hdo),
                               rtol=2e-5, atol=2e-6)


def test_derived_jax_vjp_oracle():
    """End-to-end gradient truth: the derived assembly equals jax.vjp of
    the actual mhc_post data path (f64), not merely its own reference."""
    import jax
    import jax.numpy as jnp
    from repro.models.layers import sinkhorn
    g, logits, beta = _case(16, 48, seed=3)
    M = sinkhorn(jnp.asarray(logits, jnp.float64), 5)
    b64 = jnp.asarray(beta, jnp.float64)

    def fwd(h, o):
        # models/layers.mhc_post's data path in (rows, stream, d) layout:
        # the M stream mix plus the beta-broadcast layer output
        return jnp.einsum("ij,rjd->rid", M, h) + \
            b64[None, :, None] * o[:, None, :]

    rows, n, d = g.shape
    _, vjp = jax.vjp(fwd, jnp.zeros((rows, n, d), jnp.float64),
                     jnp.zeros((rows, d), jnp.float64))
    dh_true, do_true = vjp(jnp.asarray(g, jnp.float64))
    dh, do = mhc_post_grad_derived(g, logits, beta)
    np.testing.assert_allclose(np.asarray(dh), np.asarray(dh_true),
                               rtol=3e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(do), np.asarray(do_true),
                               rtol=3e-4, atol=2e-5)
