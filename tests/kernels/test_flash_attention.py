"""Flash attention via the GENERATED fusion chain: shape/dtype sweep vs the
pure-jnp oracle (``ref.py``).  The forward no longer runs a hand-written
Pallas kernel — it compiles the proposer-derived flash_attention chain per
(Sq, Skv, D) slice geometry (DESIGN.md §13), so this file is the
end-to-end differential gate for that path: MHA/GQA/MQA head mappings,
causal and full masks, cross-length KV, explicit sm_scale folding, and a
bit-for-bit check against the reference at a resident-form geometry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (flash_attention_fwd,
                                           mha_reference, decode_reference)
from repro.kernels.flash_attention.ops import flash_attention


def _mk(B, Sq, Skv, Hq, Hkv, D, dtype, seed=0):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(B, Sq, Hq, D), dtype) * 0.5
    k = jnp.asarray(rng.randn(B, Skv, Hkv, D), dtype) * 0.5
    v = jnp.asarray(rng.randn(B, Skv, Hkv, D), dtype) * 0.5
    return q, k, v


SHAPES = [
    # (B, Sq, Skv, Hq, Hkv, D)
    (1, 128, 128, 2, 2, 64),      # MHA square
    (2, 256, 256, 4, 2, 64),      # GQA 2:1
    (1, 128, 512, 8, 1, 32),      # MQA, cross longer KV
    (2, 384, 384, 4, 4, 128),     # non-pow2 seq
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_reference_f32(shape, causal):
    B, Sq, Skv, Hq, Hkv, D = shape
    q, k, v = _mk(B, Sq, Skv, Hq, Hkv, D, jnp.float32)
    out = flash_attention_fwd(q, k, v, causal=causal)
    ref = mha_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_bit_exact_at_resident_geometry():
    """At a geometry where the whole row block is VMEM-resident the chain
    degenerates to the same dot-softmax-dot sequence the reference runs:
    the generated kernel must match ``mha_reference`` bit for bit."""
    q, k, v = _mk(2, 16, 16, 4, 2, 16, jnp.float32)
    out = flash_attention_fwd(q, k, v, causal=True)
    ref = mha_reference(q, k, v, causal=True)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_flash_explicit_sm_scale_folded_into_q():
    """The chain bakes the traced qk scale; an arbitrary sm_scale must be
    folded into q without changing the result vs the reference."""
    q, k, v = _mk(1, 64, 64, 2, 2, 32, jnp.float32)
    for s in (0.5, 0.07, 1.0):
        out = flash_attention_fwd(q, k, v, causal=True, sm_scale=s)
        ref = mha_reference(q, k, v, causal=True, sm_scale=s)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_flash_dtypes(dtype):
    q, k, v = _mk(1, 128, 128, 2, 2, 64, dtype)
    out = flash_attention_fwd(q, k, v, causal=True)
    ref = mha_reference(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)
    assert out.dtype == dtype


def test_flash_custom_vjp_grads_match_reference():
    q, k, v = _mk(1, 128, 128, 2, 2, 32, jnp.float32)

    def f_flash(q, k, v):
        return (flash_attention(q, k, v, True, None) ** 2).sum()

    def f_ref(q, k, v):
        return (mha_reference(q, k, v, causal=True) ** 2).sum()

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_decode_reference_consistent_with_full():
    """Decode (1 token vs cache) must equal the last row of full attention."""
    B, S, H, Hkv, D = 2, 64, 4, 2, 32
    q, k, v = _mk(B, S, S, H, Hkv, D, jnp.float32)
    full = mha_reference(q, k, v, causal=True)
    out = decode_reference(q[:, -1:], k, v,
                           jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(out[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-5, atol=2e-5)
