"""Differential gate for the fused decode-step attention path.

``decode_attention_fused`` runs the GENERATED flash_attention chain (the
decode extraction dedupes onto the same fingerprint — DESIGN.md §15) at a
(group, kv_len, head_dim) slice geometry with a live-prefix length mask.
This file pins the acceptance criterion: fused ≡ sequential-chain build ≡
eager decode path (``decode_reference``) across GQA/MQA/MHA head mappings
and kv lengths spanning multiple cache buckets.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import decode_reference
from repro.kernels.flash_attention.ops import decode_attention_fused
from repro.serving import decode_bucket


def _mk_decode(B, S, Hq, Hkv, D, seed=0):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(B, 1, Hq, D), jnp.float32) * 0.5
    k = jnp.asarray(rng.randn(B, S, Hkv, D), jnp.float32) * 0.5
    v = jnp.asarray(rng.randn(B, S, Hkv, D), jnp.float32) * 0.5
    # ragged live prefixes: every batch row a different cache_len
    lens = jnp.asarray(rng.randint(1, S + 1, size=(B,)), jnp.int32)
    return q, k, v, lens


SHAPES = [
    # (B, S, Hq, Hkv, D) — GQA / MQA / MHA, kv_len across distinct buckets
    (2, 16, 4, 2, 16),     # GQA 2:1, floor bucket
    (1, 32, 8, 1, 32),     # MQA, next bucket up
    (3, 64, 4, 4, 16),     # MHA, third bucket
    (2, 48, 6, 2, 32),     # GQA 3:1, non-pow2 kv_len (bucket 64)
]


def test_shapes_span_multiple_kv_buckets():
    """The sweep below is only a multi-bucket gate if the kv lengths
    actually land in distinct buckets of the serving cache key."""
    buckets = {decode_bucket(B, S)[1] for B, S, *_ in SHAPES}
    assert len(buckets) >= 3, buckets


@pytest.mark.parametrize("shape", SHAPES)
def test_decode_fused_matches_eager_reference(shape):
    """Fused generated-chain decode ≡ the eager decode path the model's
    ``apply_attention`` runs (``decode_reference``), with ragged per-batch
    cache lengths."""
    B, S, Hq, Hkv, D = shape
    q, k, v, lens = _mk_decode(B, S, Hq, Hkv, D, seed=sum(shape))
    out = decode_attention_fused(q, k, v, lens)
    ref = decode_reference(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    assert out.shape == (B, 1, Hq, D)
    assert out.dtype == q.dtype


def test_decode_fused_explicit_sm_scale():
    q, k, v, lens = _mk_decode(2, 32, 4, 2, 16, seed=7)
    for s in (0.5, 0.07, 1.0):
        out = decode_attention_fused(q, k, v, lens, sm_scale=s)
        ref = decode_reference(q, k, v, lens, sm_scale=s)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", [(2, 16, 4, 2, 16), (1, 32, 8, 1, 32)])
def test_decode_fused_matches_sequential_chain_build(shape):
    """Fused ≡ sequential: the same flash chain built with mode=
    'sequential' (every stage its own staged kernel) at the decode slice
    geometry must produce the same attention output through the artifact
    entry — the decode fast path never changes numerics, only staging."""
    from repro.core.fusion.chain import CHAINS, build_chain
    from repro.core.lowering.pipeline import transcompile

    B, S, Hq, Hkv, D = shape
    group = Hq // Hkv
    q, k, v, lens = _mk_decode(B, S, Hq, Hkv, D, seed=13)

    spec = CHAINS["flash_attention"]
    shapes = {"q": (group, D), "k": (S, D), "mask": (group, S),
              "v": (S, D), "output": (group, D)}
    prog = build_chain(spec, shapes, mode="sequential")
    entry = transcompile(prog, verify_against_interp=False).entry
    baked = float(dict(spec.attrs)["scale"])
    sm_scale = 1.0 / np.sqrt(D)

    fused = np.asarray(decode_attention_fused(q, k, v, lens))

    qf = (jnp.asarray(q, jnp.float32) * (sm_scale / baked)).reshape(
        B, Hkv, group, D)
    pos = jnp.arange(S, dtype=jnp.int32)[None, :]
    mask = jnp.where(pos < lens[:, None], 0.0, -3.0e38).astype(jnp.float32)
    for b in range(B):
        mask_b = jnp.broadcast_to(mask[b][None, :], (group, S))
        for j in range(Hkv):
            seq = np.asarray(entry(qf[b, j], k[b, :, j, :].astype(jnp.float32),
                                   mask_b, v[b, :, j, :].astype(jnp.float32)))
            got = fused[b, 0, j * group:(j + 1) * group, :]
            np.testing.assert_allclose(got, seq, rtol=2e-6, atol=2e-6)


def test_decode_fused_masks_dead_tail_exactly():
    """Positions at or beyond cache_len must contribute exactly zero:
    perturbing the dead tail of the cache cannot change the output."""
    B, S, Hq, Hkv, D = 2, 32, 4, 2, 16
    q, k, v, _ = _mk_decode(B, S, Hq, Hkv, D, seed=3)
    lens = jnp.asarray([5, 17], jnp.int32)
    out = decode_attention_fused(q, k, v, lens)
    k2 = k.at[0, 5:].set(1e4).at[1, 17:].set(-1e4)
    v2 = v.at[0, 5:].set(1e4).at[1, 17:].set(-1e4)
    out2 = decode_attention_fused(q, k2, v2, lens)
    assert np.array_equal(np.asarray(out), np.asarray(out2))
