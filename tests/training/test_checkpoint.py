"""Checkpoint manager: roundtrip, atomicity, retention, resume determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.models import transformer as T
from repro.training import optimizer as opt
from repro.training.train import make_train_step


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = _tree()
    mgr.save(3, tree, meta={"data_step": 3, "note": "x"})
    assert mgr.latest_step() == 3
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, meta = mgr.restore(3, like)
    assert meta["data_step"] == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.all_steps() == [3, 4]


def test_incomplete_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=False)
    mgr.save(1, _tree())
    # simulate a crash mid-write: directory without the commit marker
    os.makedirs(tmp_path / "step_2")
    (tmp_path / "step_2" / "manifest.json").write_text("{}")
    assert mgr.latest_step() == 1


def test_async_writer(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=True)
    mgr.save(5, _tree())
    mgr.wait()
    assert mgr.latest_step() == 5


def test_resume_is_bit_deterministic(tmp_path):
    """Train 6 steps; vs train 3, checkpoint, restart from it, 3 more —
    identical parameters (data cursor + opt state ride the checkpoint)."""
    cfg = get_config("internlm2-1.8b", smoke=True).scaled(vocab=64)
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=1000)
    data = SyntheticLM(DataConfig(vocab=64, seq_len=32, global_batch=4))
    step_fn = jax.jit(make_train_step(cfg, ocfg))

    def run(params, state, start, n):
        for s in range(start, start + n):
            b = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
            params, state, _ = step_fn(params, state, b)
        return params, state

    p0 = T.init_params(jax.random.PRNGKey(0), cfg)
    s0 = opt.init(p0)
    pA, sA = run(p0, s0, 0, 6)

    pB, sB = run(p0, s0, 0, 3)
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(3, {"params": pB, "opt": sB}, meta={"data_step": 3})
    restored, meta = mgr.restore(3, {"params": pB, "opt": sB})
    pC, sC = run(restored["params"], restored["opt"], meta["data_step"], 3)

    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pC)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
