"""Optimizer math, grad accumulation equivalence, loss-goes-down."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.models import transformer as T
from repro.training import optimizer as opt
from repro.training.train import make_train_step


def test_adamw_matches_reference_math():
    ocfg = opt.AdamWConfig(lr=1e-2, weight_decay=0.1, grad_clip=0.0,
                           warmup_steps=0, total_steps=10**9)
    params = {"w": jnp.asarray(np.arange(4, dtype=np.float32))}
    grads = {"w": jnp.asarray([0.1, -0.2, 0.3, -0.4], jnp.float32)}
    state = opt.init(params)
    new_p, state, _ = opt.apply(ocfg, params, state, grads)
    g = np.asarray(grads["w"], np.float64)
    m = 0.1 * g
    v = 0.05 * g * g
    up = 1e-2 * (m / (1 - 0.9)) / (np.sqrt(v / (1 - 0.95)) + 1e-8) \
        + 1e-2 * 0.1 * np.arange(4)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               np.arange(4) - up, rtol=1e-5)


def test_lr_schedule_warmup_and_decay():
    ocfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                           min_lr_ratio=0.1)
    assert float(opt.lr_schedule(ocfg, 5)) < 1.0
    assert abs(float(opt.lr_schedule(ocfg, 10)) - 1.0) < 1e-6
    assert abs(float(opt.lr_schedule(ocfg, 100)) - 0.1) < 1e-6


def test_grad_accum_equivalent_to_full_batch():
    cfg = get_config("internlm2-1.8b", smoke=True)
    ocfg = opt.AdamWConfig(lr=1e-3, grad_clip=0.0, warmup_steps=0)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    state = opt.init(params)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (4, 32)),
                                   jnp.int32)}
    p1, _, m1 = make_train_step(cfg, ocfg, grad_accum=1)(params, state,
                                                         batch)
    p2, _, m2 = make_train_step(cfg, ocfg, grad_accum=4)(params, state,
                                                         batch)
    # bf16 params + different accumulation order: tolerate a few ulps
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 3e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-2, atol=2e-3)


def test_loss_decreases_on_synthetic_stream():
    cfg = get_config("internlm2-1.8b", smoke=True).scaled(vocab=64)
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100)
    data = SyntheticLM(DataConfig(vocab=64, seq_len=64, global_batch=8))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, ocfg))
    losses = []
    for step in range(30):
        b = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        params, state, m = step_fn(params, state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.4, losses[:3] + losses[-3:]


def test_data_pipeline_deterministic():
    d1 = SyntheticLM(DataConfig(vocab=64, seq_len=32, global_batch=4))
    d2 = SyntheticLM(DataConfig(vocab=64, seq_len=32, global_batch=4))
    np.testing.assert_array_equal(d1.batch(7)["tokens"],
                                  d2.batch(7)["tokens"])
    assert not np.array_equal(d1.batch(7)["tokens"], d1.batch(8)["tokens"])


def test_fused_backward_train_step_matches_xla_backward():
    """Tentpole wiring (DESIGN.md §16): make_train_step(fused_backward=
    True) routes the mHC stream mixers through the custom-VJP variant
    whose backward runs the EXTRACTED mhc_stream_bwd fusion chain.  One
    full train step (loss -> grads -> AdamW) must agree with the XLA
    autodiff step at f32 tolerance on an mHC-enabled config."""
    cfg = get_config("internlm2-1.8b", smoke=True).scaled(
        hyper_connections=4, dtype="float32", vocab=64)
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    data = SyntheticLM(DataConfig(vocab=64, seq_len=16, global_batch=2))
    b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    p1, s1, m1 = make_train_step(cfg, ocfg)(params, opt.init(params), b)
    p2, s2, m2 = make_train_step(cfg, ocfg, fused_backward=True)(
        params, opt.init(params), b)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-6
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32),
                                   rtol=2e-4, atol=2e-5)


def test_fused_backward_grads_match_jax_grad_oracle():
    """jax.grad oracle at the gradient level: the fused-backward loss
    gradients equal XLA autodiff's on every mHC parameter and the dense
    weights they feed (the custom VJP covers d_streams/d_layer_out via
    the generated chain AND the sinkhorn-pullback parameter grads)."""
    from repro.models import layers as L
    cfg = get_config("internlm2-1.8b", smoke=True).scaled(
        hyper_connections=4, dtype="float32")
    rng = np.random.RandomState(3)
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (2, 16)), jnp.int32)
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    loss = lambda p: T.loss_fn(p, cfg, {"tokens": toks})  # noqa: E731
    l0, g0 = jax.value_and_grad(loss)(params)
    with L.mhc_post_impl("fused_bwd"):
        l1, g1 = jax.value_and_grad(loss)(params)
    assert float(jnp.abs(l0 - l1)) < 1e-6
    flat0, flat1 = jax.tree.leaves(g0), jax.tree.leaves(g1)
    assert max(float(jnp.max(jnp.abs(a))) for a in flat0) > 0.1
    for a, c in zip(flat0, flat1):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=3e-4, atol=2e-5)
