"""End-to-end launcher test: train a few steps, kill, auto-resume (the
fault-tolerance loop of launch/train.py)."""
import os
import subprocess
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _train(ckpt_dir, steps):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--steps", str(steps), "--seq-len", "32", "--batch", "4",
         "--ckpt-every", "5", "--ckpt-dir", ckpt_dir],
        capture_output=True, text=True, timeout=420, env=env, cwd=_ROOT)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_train_launcher_runs_and_resumes(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    out1 = _train(ckpt, steps=7)
    assert "step    5" in out1 or "step 5" in out1.replace("   ", " ")
    assert "done" in out1
    # second invocation must auto-resume from the last checkpoint
    out2 = _train(ckpt, steps=12)
    assert "[resume] from step 7" in out2, out2
    assert "done" in out2
