"""Property tests over pooling kernel parameters (k, s, H, W): the baseline
and row-reuse generated kernels must agree with numpy for arbitrary
window/stride/shape combinations."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.dsl.ast import DType
from repro.core.examples.pooling import build_pool2d_rowreuse
from repro.core.lowering.pipeline import Knobs, transcompile
from repro.core.planner import PLANNER_REGISTRY
from repro.core.task import KernelTask, TensorSpec
from tests.conftest import *  # noqa: F401,F403


def _task(op, B, C, H, W, k, s):
    Ho, Wo = (H - k) // s + 1, (W - k) // s + 1
    shapes = {"input": (B, C, H, W), "output": (B, C, Ho, Wo)}
    return KernelTask(
        name=op, category="pooling", op=op,
        tensors=[TensorSpec("input", DType.f32, "in", 4),
                 TensorSpec("output", DType.f32, "out", 4)],
        shapes=shapes, check_shapes=shapes, ref=None,
        attrs={"kernel": k, "stride": s})


def _np_pool2d(x, k, s, mode):
    B, C, H, W = x.shape
    Ho, Wo = (H - k) // s + 1, (W - k) // s + 1
    out = np.full((B, C, Ho, Wo), 0.0 if mode == "avg" else -np.inf)
    for kh in range(k):
        for kw in range(k):
            sl = x[:, :, kh: kh + (Ho - 1) * s + 1: s,
                   kw: kw + (Wo - 1) * s + 1: s]
            out = out + sl if mode == "avg" else np.maximum(out, sl)
    return out / (k * k) if mode == "avg" else out


@settings(max_examples=8, deadline=None)
@given(
    k=st.integers(min_value=2, max_value=4),
    s=st.integers(min_value=1, max_value=3),
    H=st.integers(min_value=8, max_value=24),
    W=st.integers(min_value=8, max_value=40),
    mode=st.sampled_from(["avg", "max"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pool2d_baseline_and_rowreuse_agree(k, s, H, W, mode, seed):
    if s > k or H < k or W < k:
        return
    task = _task(f"{mode}_pool2d", 2, 2, H, W, k, s)
    x = np.random.RandomState(seed).randn(2, 2, H, W).astype(np.float32)
    want = _np_pool2d(x.astype(np.float64), k, s, mode)

    base = transcompile(PLANNER_REGISTRY[f"{mode}_pool2d"](
        task, task.shapes, Knobs()))
    got = np.asarray(base.entry(x, interpret=True))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    rr = transcompile(build_pool2d_rowreuse(task, task.shapes, Knobs(),
                                            mode))
    got2 = np.asarray(rr.entry(x, interpret=True))
    np.testing.assert_allclose(got2, want, rtol=1e-5, atol=1e-6)
