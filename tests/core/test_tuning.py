"""Autotuning + artifact cache (DESIGN.md §8): cache hit/miss/invalidation,
tuner determinism under a fixed budget, and autonomous discovery of the
pool2d row-reuse variant."""
import numpy as np
import pytest

from repro.bench import suite
from repro.core.lowering.pipeline import PIPELINE_COUNTERS, Knobs
from repro.core.planner import generate
from repro.core.tuning import ArtifactCache, Candidate, tune, variants_for


@pytest.fixture(scope="module")
def tasks():
    return {t.name: t for t in suite()}


def _counters():
    return dict(PIPELINE_COUNTERS)


# ---------------------------------------------------------------------------
# Artifact cache
# ---------------------------------------------------------------------------

def test_cache_hit_skips_lowering(tasks, tmp_path):
    """Second generate() of an identical task must come from the cache with
    NO lowering-pass work (transcompile/feedback counters frozen)."""
    cache = ArtifactCache(str(tmp_path))
    task = tasks["relu"]

    r1 = generate(task, cache=cache)
    assert r1.comp_ok and r1.pass_ok and not r1.cached
    after_first = _counters()

    r2 = generate(task, cache=cache)
    assert r2.cached and r2.comp_ok and r2.pass_ok
    assert _counters() == after_first, \
        "cache hit re-ran the lowering pipeline"
    assert any("cache/hit" in line for line in r2.artifact.pass_log)
    assert any("lowering pipeline skipped" in line
               for line in r2.artifact.pass_log)

    # the cached artifact is the same source and still executes
    assert r2.artifact.source == r1.artifact.source
    x = np.random.RandomState(0).randn(
        *task.check_shapes["input"]).astype(np.float32)
    art = generate(task, cache=cache).artifact   # hit again
    fn = art.module.make({"input": x.shape, "output": x.shape},
                         interpret=True)
    np.testing.assert_allclose(np.asarray(fn(x)), np.maximum(x, 0),
                               rtol=1e-6, atol=1e-6)


def test_cache_key_distinguishes_knobs_and_misses(tasks, tmp_path):
    cache = ArtifactCache(str(tmp_path))
    task = tasks["relu"]
    k_default = cache.key_for(task, Knobs())
    k_tile = cache.key_for(task, Knobs(max_tile=512))
    k_variant = cache.key_for(task, Knobs(), variant="other")
    assert len({k_default, k_tile, k_variant}) == 3
    assert cache.get(k_default) is None          # empty cache: miss
    assert cache.misses == 1 and cache.hits == 0


def test_cache_invalidated_on_codegen_version_bump(tasks, tmp_path,
                                                   monkeypatch):
    cache = ArtifactCache(str(tmp_path))
    task = tasks["relu"]
    generate(task, verify=False, cache=cache)
    assert generate(task, verify=False, cache=cache).cached

    import repro.core.codegen.emit as emit
    monkeypatch.setattr(emit, "CODEGEN_VERSION", emit.CODEGEN_VERSION + 1)
    r = generate(task, verify=False, cache=cache)
    assert not r.cached, "codegen version bump must invalidate the cache"
    # and the rebuilt artifact is cached under the NEW version
    assert generate(task, verify=False, cache=cache).cached


def test_cache_unverified_entry_reverified_cheaply(tasks, tmp_path):
    """An entry stored without a verdict must be re-verified under
    verify=True — but the bench artifact still comes from the cache, so
    only the check-shape build pays the lowering pipeline."""
    cache = ArtifactCache(str(tmp_path))
    task = tasks["relu"]
    generate(task, verify=False, cache=cache)        # stores pass_ok=None
    before = _counters()
    r = generate(task, verify=True, cache=cache)     # must re-verify
    assert r.cached and r.pass_ok
    delta = _counters()["transcompile"] - before["transcompile"]
    assert delta == 1, f"expected only the check-shape build, got {delta}"
    before = _counters()
    assert generate(task, verify=True, cache=cache).cached
    assert _counters() == before                     # verdict now covers


def test_verdict_coverage_is_one_sided():
    """PASS at strict tolerances covers looser requests; FAIL at loose
    tolerances covers stricter requests — never the other way around."""
    passed = {"pass_ok": True, "verify_rtol": 1e-6, "verify_atol": 1e-8}
    failed = {"pass_ok": False, "verify_rtol": 1e-3, "verify_atol": 1e-4}
    assert ArtifactCache.verdict_covers(passed, 1e-4, 1e-5)      # looser req
    assert not ArtifactCache.verdict_covers(passed, 1e-9, 1e-12)
    assert ArtifactCache.verdict_covers(failed, 1e-6, 1e-8)      # stricter req
    assert not ArtifactCache.verdict_covers(failed, 1e-2, 1e-2)


def test_failed_strict_verdict_not_served_for_looser_request(
        tasks, tmp_path):
    """A kernel that fails only at ultra-strict tolerances must still pass
    (and be re-verified) at the default tolerances afterwards."""
    cache = ArtifactCache(str(tmp_path))
    task = tasks["softmax"]          # f32 kernel vs f64 ref: err ~1e-7
    r_strict = generate(task, rtol=1e-13, atol=1e-16, cache=cache)
    assert not r_strict.pass_ok      # stored pass_ok=False at strict tols
    r_default = generate(task, cache=cache)
    assert r_default.pass_ok, \
        "strict-tolerance failure must not be served for a looser request"


def test_cache_verdict_not_served_at_stricter_tolerance(tasks, tmp_path):
    cache = ArtifactCache(str(tmp_path))
    task = tasks["relu"]
    generate(task, cache=cache)                     # verified at defaults
    before = _counters()
    r = generate(task, rtol=1e-9, atol=1e-12, cache=cache)
    delta = _counters()["transcompile"] - before["transcompile"]
    assert delta == 1, "stricter tolerances must force re-verification"
    assert r.pass_ok                                # relu is numerically exact
    # the stricter verdict is now stored and covers the default request too
    before = _counters()
    assert generate(task, cache=cache).cached
    assert _counters() == before


# ---------------------------------------------------------------------------
# Tuner
# ---------------------------------------------------------------------------

def test_tuner_deterministic_under_fixed_budget(tasks, tmp_path):
    task = tasks["relu"]
    runs = []
    for i in range(2):
        tr = tune(task, budget=4, cache=str(tmp_path / f"c{i}"))
        runs.append([(t.candidate, round(t.ratio, 12), t.ok)
                     for t in tr.trials])
        assert tr.evaluations <= 4
    assert runs[0] == runs[1], "tuner must be deterministic"


def test_tuner_persists_gate_verdict_for_cached_entries(tasks, tmp_path):
    """Gating an unverified cached entry writes the verdict back, so later
    tunes/generates never re-pay the check-shape build for it."""
    cache = ArtifactCache(str(tmp_path))
    task = tasks["relu"]
    generate(task, verify=False, cache=cache)        # stores pass_ok=None
    tr = tune(task, budget=1, cache=cache)
    key = cache.key_for(task, tr.best.candidate.to_knobs())
    assert cache.get(key).meta["pass_ok"] is True
    assert generate(task, verify=True, cache=cache).cached


def test_tuner_respects_budget(tasks, tmp_path):
    tr = tune(tasks["avg_pool2d"], budget=2, cache=str(tmp_path))
    assert tr.evaluations == 2 == len(tr.trials)


def test_tuner_discovers_pool2d_rowreuse(tasks, tmp_path):
    """The acceptance bar: no hand-wiring — the hill climb finds the
    row-reuse dataflow on its own and it models >= 1.2x the default."""
    task = tasks["avg_pool2d"]
    assert set(variants_for(task.op)) >= {"default", "rowreuse"}
    tr = tune(task, budget=6, cache=str(tmp_path))
    assert tr.best.candidate.variant == "rowreuse", tr.summary()
    assert tr.best.ok
    assert tr.improvement >= 1.2, tr.summary()


def test_generate_tune_uses_tuned_variant_and_pointer(tasks, tmp_path):
    cache = ArtifactCache(str(tmp_path))
    task = tasks["max_pool2d"]
    r = generate(task, tune=True, tune_budget=6, cache=cache)
    assert r.comp_ok and r.pass_ok
    assert r.tune is not None
    assert r.tune.best.candidate.variant == "rowreuse"
    assert r.artifact.program.name.endswith("_rowreuse")

    # second tuned call: candidate comes from the tuned pointer, artifact
    # from the cache — no search, no lowering
    before = _counters()
    r2 = generate(task, tune=True, tune_budget=6, cache=cache)
    assert r2.cached and r2.tune is None
    assert _counters() == before
    assert r2.artifact.program.name.endswith("_rowreuse")


def test_tuned_pointer_survives_constrained_search(tasks, tmp_path):
    """A narrower later search must not clobber a better stored pointer."""
    cache = ArtifactCache(str(tmp_path))
    task = tasks["avg_pool2d"]
    generate(task, tune=True, tune_budget=6, cache=cache)
    rec1 = cache.get_tuned(task)
    assert rec1["candidate"]["variant"] == "rowreuse"
    generate(task, knobs=Knobs(max_tile=256), tune=True, tune_budget=1,
             cache=cache)
    assert cache.get_tuned(task) == rec1


# ---------------------------------------------------------------------------
# Self-healing cache (DESIGN.md §14): a damaged on-disk entry is evicted and
# regenerated instead of raising into the caller
# ---------------------------------------------------------------------------

def _corrupt_and_heal(tasks, tmp_path, damage):
    cache = ArtifactCache(str(tmp_path))
    task = tasks["relu"]
    r1 = generate(task, verify=False, cache=cache)
    assert r1.comp_ok and not r1.cached
    key = cache.key_for(task, Knobs())
    damage(cache, key)
    r2 = generate(task, verify=False, cache=cache)   # heals: evict + rebuild
    assert r2.comp_ok and not r2.cached
    assert cache.evictions == 1, "damaged entry must be evicted, not served"
    assert r2.artifact.source == r1.artifact.source
    assert generate(task, verify=False, cache=cache).cached  # re-stored


def test_cache_heals_truncated_meta_json(tasks, tmp_path):
    def damage(cache, key):
        p = cache.root / f"{key}.json"
        p.write_text(p.read_text()[: len(p.read_text()) // 2])
    _corrupt_and_heal(tasks, tmp_path, damage)


def test_cache_heals_checksum_mismatch(tasks, tmp_path):
    def damage(cache, key):
        (cache.root / f"{key}.py").write_text("def broken(: pass\n")
    _corrupt_and_heal(tasks, tmp_path, damage)


def test_cache_heals_schema_or_version_skew(tasks, tmp_path):
    import json as _json

    def damage(cache, key):
        p = cache.root / f"{key}.json"
        meta = _json.loads(p.read_text())
        meta["codegen_version"] = -1       # entry from an alien codegen
        p.write_text(_json.dumps(meta))
    _corrupt_and_heal(tasks, tmp_path, damage)


def test_cache_entry_damage_classifier():
    import hashlib
    from repro.core.codegen import emit
    from repro.core.tuning.cache import CACHE_SCHEMA_VERSION
    src = "def k(): pass\n"
    ok = {"schema": CACHE_SCHEMA_VERSION,
          "codegen_version": emit.CODEGEN_VERSION,
          "checksum": hashlib.sha256(src.encode()).hexdigest()}
    assert ArtifactCache._entry_damage(ok, src) is None
    assert "not an object" in ArtifactCache._entry_damage("nope", src)
    assert "schema" in ArtifactCache._entry_damage({**ok, "schema": 99}, src)
    assert "codegen" in ArtifactCache._entry_damage(
        {**ok, "codegen_version": -1}, src)
    assert "checksum" in ArtifactCache._entry_damage(ok, src + "# tampered")


# ---------------------------------------------------------------------------
# Serving warm-up wiring
# ---------------------------------------------------------------------------

def test_serving_warm_kernel_cache(tasks, tmp_path):
    from repro.serving.engine import warm_kernel_cache
    sub = [tasks["relu"]]
    rep1 = warm_kernel_cache(cache=str(tmp_path), tasks=sub)
    assert rep1["kernels"][0]["comp_ok"]
    assert not rep1["kernels"][0]["from_cache"]
    rep2 = warm_kernel_cache(cache=str(tmp_path), tasks=sub)
    assert rep2["kernels"][0]["from_cache"]


# ---------------------------------------------------------------------------
# DMA-burst tie-break (DESIGN.md §10): equal modeled bytes, fewer transfers
# ---------------------------------------------------------------------------

def test_tuner_discovers_mhc_rowblock_by_transfer_tiebreak(tmp_path):
    """ROADMAP item: the row-blocked mHC kernel (paper RQ3 'bigger DMA
    bursts' step) is a register_variant entry the tuner discovers — it
    moves the SAME bytes (the roofline ratio ties to ~1e-6), so the win
    comes from the transfer-count tie-break, not a ratio edge."""
    from repro.bench.mhc import mhc_tasks
    from repro.core.tuning import tune, variants_for

    assert "rowblock" in variants_for("mhc_post")
    task = mhc_tasks()[0]
    tr = tune(task, budget=8, cache=str(tmp_path))
    assert tr.best.ok
    assert tr.best.candidate.variant == "rowblock", tr.best.candidate
    default = next(t for t in tr.trials
                   if t.candidate.variant == "default")
    assert tr.best.transfers < default.transfers / 10
    assert abs(tr.best.ratio - default.ratio) <= 1e-3 * default.ratio
