"""Widened coverage: bf16 generation paths, the Figure-2 streaming builders,
and planner behavior on VMEM-overflow rows."""
import numpy as np
import pytest

from repro.core.dsl.ast import DType
from repro.core.lowering.pipeline import Knobs, transcompile
from repro.core.planner import PLANNER_REGISTRY, default_inputs, generate
from repro.core.task import KernelTask, TensorSpec


def _unary_task(op, shapes, dtype=DType.f32):
    return KernelTask(
        name=op, category="activation", op=op,
        tensors=[TensorSpec("input", dtype, "in", len(shapes)),
                 TensorSpec("output", dtype, "out", len(shapes))],
        shapes={"input": shapes, "output": shapes},
        check_shapes={"input": shapes, "output": shapes},
        ref=None, attrs={"input": "input", "output": "output"})


@pytest.mark.parametrize("op,npref", [
    ("tanh", np.tanh),
    ("relu", lambda x: np.maximum(x, 0)),
    ("sigmoid", lambda x: 1 / (1 + np.exp(-x.astype(np.float64)))),
])
def test_bf16_elementwise_generation(op, npref):
    """DSL bf16 buffers end-to-end: generation, cast emission, tolerance."""
    import ml_dtypes
    shapes = (64, 384)
    task = _unary_task(op, shapes, DType.bf16)
    prog = PLANNER_REGISTRY[op](task, task.shapes, Knobs())
    art = transcompile(prog)
    rng = np.random.RandomState(0)
    x = rng.randn(*shapes).astype(ml_dtypes.bfloat16)
    out = np.asarray(art.entry(x, interpret=True), dtype=np.float32)
    want = npref(x.astype(np.float32))
    np.testing.assert_allclose(out, want, rtol=2e-2, atol=2e-2)
    assert art.program.kernel.tensors[0].dtype is DType.bf16


def test_streaming_softmax_builder_direct():
    """The 2-pass ONLINE streaming softmax (DESIGN.md §12 — running max +
    rescaled denominator, replacing the paper's 3-pass Fig.-2 program),
    exercised directly (the resident path normally wins at test sizes)."""
    from repro.core.examples.normalization import build_softmax_streaming
    shapes = {"input": (32, 1024), "output": (32, 1024)}
    task = _unary_task("softmax", (32, 1024))
    task.attrs["pad_value"] = -3.0e38
    prog = build_softmax_streaming(task, shapes, Knobs(max_tile=256))
    art = transcompile(prog)
    assert art.backend == "explicit"         # running scalars -> explicit
    x = np.random.RandomState(0).randn(32, 1024).astype(np.float32)
    out = np.asarray(art.entry(x, interpret=True))
    e = np.exp(x - x.max(-1, keepdims=True))
    np.testing.assert_allclose(out, e / e.sum(-1, keepdims=True),
                               rtol=1e-4, atol=1e-6)


def test_streaming_log_softmax_builder_direct():
    """The log-form online streaming builder (same (m, d) recurrence;
    pass 2 subtracts m + log d), registered as the planner's
    log_softmax_streaming fallback."""
    from repro.core.examples.normalization import build_log_softmax_streaming
    shapes = {"input": (16, 1024), "output": (16, 1024)}
    task = _unary_task("log_softmax", (16, 1024))
    task.attrs["pad_value"] = -3.0e38
    prog = build_log_softmax_streaming(task, shapes, Knobs(max_tile=256))
    art = transcompile(prog)
    assert art.backend == "explicit"
    x = np.random.RandomState(1).randn(16, 1024).astype(np.float32)
    out = np.asarray(art.entry(x, interpret=True))
    m = x.max(-1, keepdims=True)
    want = x - m - np.log(np.exp(x - m).sum(-1, keepdims=True))
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


def test_streaming_rmsnorm_builder_direct():
    from repro.core.examples.normalization import build_rmsnorm_streaming
    shapes = {"input": (16, 2048), "weight": (2048,), "output": (16, 2048)}
    task = KernelTask(
        name="rmsnorm", category="normalization", op="rmsnorm",
        tensors=[TensorSpec("input", DType.f32, "in", 2),
                 TensorSpec("weight", DType.f32, "in", 1),
                 TensorSpec("output", DType.f32, "out", 2)],
        shapes=shapes, check_shapes=shapes, ref=None, attrs={})
    prog = build_rmsnorm_streaming(task, shapes, Knobs(max_tile=512))
    art = transcompile(prog)
    rng = np.random.RandomState(1)
    x = rng.randn(16, 2048).astype(np.float32)
    w = rng.randn(2048).astype(np.float32)
    out = np.asarray(art.entry(x, w, interpret=True))
    x64 = x.astype(np.float64)
    want = x64 / np.sqrt((x64 ** 2).mean(-1, keepdims=True) + 1e-6) * w
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


def test_planner_falls_back_to_streaming_on_vmem_overflow():
    """Rows too long for VMEM residency must route to the streaming example
    (the planner's NotImplementedError fallback)."""
    cols = 1 << 21                      # 2M f32 = 8 MB > budget/live
    from repro.bench.tasks import _softmax
    task = KernelTask(
        name="softmax", category="normalization", op="softmax",
        tensors=[TensorSpec("input", DType.f32, "in", 2),
                 TensorSpec("output", DType.f32, "out", 2)],
        shapes={"input": (32, cols), "output": (32, cols)},
        check_shapes={"input": (8, 4096), "output": (8, 4096)},
        ref=_softmax, attrs={"pad_value": -3.0e38})
    r = generate(task)
    assert r.comp_ok and r.pass_ok, r.error
    # the bench-shape artifact must be the streaming (explicit) program
    assert r.artifact.backend == "explicit"
    assert "streaming" in r.artifact.program.rationale


def test_hlo_stats_parser_robustness():
    from repro.launch.hlo_stats import collective_bytes
    # async pairs, tuple results, -done lines must not double count
    hlo = """
      %ag-start = (bf16[8,16]{1,0}, bf16[64,16]{1,0}) all-gather-start(%x)
      %ag-done = bf16[64,16]{1,0} all-gather-done(%ag-start)
      %weird = token[] after-all()
      %cp = f32[2,2]{1,0} collective-permute(%z)
    """
    out = collective_bytes(hlo)
    assert out["all-gather"] == 64 * 16 * 2
    assert out["collective-permute"] == 16
    assert collective_bytes("")["total"] == 0
