"""DSL-level kernel fusion (DESIGN.md §9): legality, numerics, VMEM
fallback, tuner discovery, traffic parity and cache fingerprints."""
import numpy as np
import pytest

from repro.bench.model import analyze_program, fast_ratio, _padded_shapes_for
from repro.bench.tasks import fused_suite, fused_task
from repro.core.dsl import ast as A
from repro.core.dsl.interp import interpret
from repro.core.fusion import (CHAINS, ChainSpec, ChainStage, FusionError,
                               build_chain, build_fused)
from repro.core.lowering.pipeline import Knobs, generate_with_feedback
from repro.core.planner import (PLANNER_REGISTRY, check_artifact_numerics,
                                generate, resolve_and_build)
from repro.core.tuning import ArtifactCache, tune, variants_for


@pytest.fixture(scope="module")
def tasks():
    return {t.name: t for t in fused_suite()}


def _build(task, variant, shapes):
    builder = variants_for(task.op)[variant]
    return builder(task, shapes, Knobs())


# ---------------------------------------------------------------------------
# End-to-end numerics: every fused chain verifies in interpreter mode
# ---------------------------------------------------------------------------

def test_fused_tasks_generate_and_verify(tasks):
    """The planner default (unfused sequential / hand-written) passes
    Comp@1 + Pass@1 for every chain task."""
    for task in tasks.values():
        r = generate(task)
        assert r.comp_ok and r.pass_ok, (task.name, r.error)


def test_fused_variant_passes_interpreter_verification(tasks):
    """The FUSED program of every chain matches the composed float64
    reference at check shapes under the Pallas interpreter."""
    for task in tasks.values():
        art = generate_with_feedback(
            lambda kn, t=task: _build(t, "fused", t.check_shapes),
            Knobs(), check_shapes=None, verify_against_interp=False)
        assert art.program.name.endswith("_fused")
        chk = check_artifact_numerics(task, art)
        assert chk.pass_ok, (task.name, chk.error)


def test_fused_handles_non_lane_multiple_columns():
    """Pad-neutrality: the computed intermediate must carry the consumer's
    neutral pad (mul_softmax pads input=-3e38, scale=1.0) so a fused
    reduction stays correct when the trailing dim is padded to the lane."""
    shp = {"input": (8, 100), "scale": (100,), "output": (8, 100)}
    task = fused_task("mul_softmax", shp, shp.copy(),
                      ref=lambda x, s: _softmax64(x, s))
    for variant in ("default", "fused"):
        art = generate_with_feedback(
            lambda kn: _build(task, variant, task.check_shapes),
            Knobs(), check_shapes=None, verify_against_interp=False)
        chk = check_artifact_numerics(task, art)
        assert chk.pass_ok, (variant, chk.error)


def _softmax64(x, s):
    v = np.asarray(x, np.float64) * np.asarray(s, np.float64)
    e = np.exp(v - v.max(-1, keepdims=True))
    return e / e.sum(-1, keepdims=True)


# ---------------------------------------------------------------------------
# Traffic: fused deletes the HBM round trip; add_rmsnorm parity
# ---------------------------------------------------------------------------

def _bytes(task, prog):
    return analyze_program(prog,
                           _padded_shapes_for(prog, task.shapes)).bytes_total


def test_fused_traffic_strictly_below_sequential(tasks):
    for task in tasks.values():
        seq = _build(task, "sequential"
                     if "sequential" in variants_for(task.op) else "default",
                     task.shapes)
        fused = _build(task, "fused", task.shapes)
        assert _bytes(task, fused) < _bytes(task, seq), task.name
        # the fused single-visit program is pipelined-eligible; the
        # sequential GM round trip (and any streaming program) forces the
        # explicit backend
        from repro.core.lowering.analysis import pipelined_eligible
        if fused.meta["fusion"]["pattern"] == "resident":
            assert pipelined_eligible(fused) is not None
        else:
            assert pipelined_eligible(fused) is None
        assert pipelined_eligible(seq) is None


def test_auto_fused_add_rmsnorm_matches_handwritten_bytes(tasks):
    """Acceptance bar: the chain auto-derived from add + rmsnorm moves the
    same HBM bytes as the hand-written build_add_rmsnorm (within 5%)."""
    task = tasks["add_rmsnorm"]
    hand = PLANNER_REGISTRY["add_rmsnorm"](task, task.shapes, Knobs())
    auto = _build(task, "fused", task.shapes)
    b_hand, b_auto = _bytes(task, hand), _bytes(task, auto)
    assert abs(b_auto - b_hand) <= 0.05 * b_hand, (b_auto, b_hand)


# ---------------------------------------------------------------------------
# Tuner discovery: fused-vs-unfused is a searchable variant axis
# ---------------------------------------------------------------------------

def test_tuner_discovers_fusion(tasks, tmp_path):
    """Acceptance bar: the hill climb picks the fused variant on its own
    for >= 2 chains, each modeling >= 1.3x the unfused sequential
    baseline."""
    wins = 0
    for name in ("bias_gelu", "mul_softmax", "rmsnorm_swiglu"):
        tr = tune(tasks[name], budget=6, cache=str(tmp_path / name))
        assert tr.best.ok
        if tr.best.candidate.variant == "fused" and tr.improvement >= 1.3:
            wins += 1
    assert wins >= 2, f"only {wins} chains tuned into fusion"


def test_tuner_discovers_proposed_streaming_and_dag_chains(tasks, tmp_path):
    """Acceptance bar (PR 3): the two NEW proposer-derived chains — one
    streaming-pattern (attn_scores: rows too wide for residency, fused by
    the loop-carry stitcher) and one DAG-shaped (swiglu_proj: shared
    producer input, scratch-routed sequential baseline) — are
    tuner-discovered at >= 1.3x their sequential baselines."""
    for name, pattern in (("attn_scores", "streaming"),
                          ("swiglu_proj", "resident")):
        task = tasks[name]
        tr = tune(task, budget=6, cache=str(tmp_path / name))
        assert tr.best.ok, tr.best.error
        assert tr.best.candidate.variant == "fused", name
        assert tr.improvement >= 1.3, (name, tr.improvement)
        prog = _build(task, "fused", task.shapes)
        assert prog.meta["fusion"]["pattern"] == pattern, name


def test_streaming_is_a_searchable_variant(tmp_path):
    """ROADMAP item: the resident-vs-streaming normalization fallback is a
    register_variant axis the tuner can evaluate (and correctly rejects —
    streaming re-reads each row, so resident wins on traffic)."""
    from repro.bench import suite
    task = {t.name: t for t in suite()}["softmax"]
    assert {"default", "streaming"} <= set(variants_for("softmax"))
    assert {"default", "streaming"} <= set(variants_for("rmsnorm"))
    tr = tune(task, budget=4, cache=str(tmp_path))
    streaming = [t for t in tr.trials
                 if t.candidate.variant == "streaming"]
    assert streaming and streaming[0].ok, "streaming variant did not build"
    assert tr.best.candidate.variant == "default"
    assert streaming[0].ratio < tr.best.ratio


# ---------------------------------------------------------------------------
# VMEM refusal -> unfused fallback
# ---------------------------------------------------------------------------

_WIDE = ChainSpec(
    name="wide_add_gelu",
    inputs=(("input", 2), ("other", 2)),
    outputs=("output",),
    stages=(ChainStage("add", ("input", "other"), "h"),
            ChainStage("gelu", ("h",), "output")))
# fused footprint at block_rows=1 is 4 row tiles (input, other, sum, gelu
# temp); the sequential baseline reuses stage-0 tiles and needs only 3 —
# a column count between the two refusal points exercises the fallback
_WIDE_SHAPES = {"input": (1, 589824), "other": (1, 589824),
                "output": (1, 589824)}


def test_fused_vmem_refusal_streams_instead_of_unfusing():
    """PR 2 behavior: a row too wide for residency lost fusion entirely.
    The loop-carry stitcher now keeps the chain fused in streaming form;
    only pattern='resident' still refuses."""
    with pytest.raises(NotImplementedError):
        build_chain(_WIDE, _WIDE_SHAPES, mode="fused", pattern="resident")
    prog = build_fused(_WIDE, _WIDE_SHAPES, fallback=True)
    assert prog.meta["fusion"]["mode"] == "fused"
    assert prog.meta["fusion"]["pattern"] == "streaming"
    # and the chain still covers every element: interpreter smoke run
    rng = np.random.RandomState(0)
    small = {"input": (2, 256), "other": (2, 256), "output": (2, 256)}
    sprog = build_chain(_WIDE, small, mode="sequential")
    x = rng.randn(2, 256).astype(np.float32)
    o = rng.randn(2, 256).astype(np.float32)
    out = interpret(sprog, {"input": x, "other": o},
                    {"output": (2, 256)})["output"]
    assert np.isfinite(out).all()


def test_multi_stat_wide_chain_fuses_streaming():
    """Two scalar recurrences (softmax -> softmax) loop-carry stitch at
    streaming scale via the per-stat spill schedule (DESIGN.md §12) — this
    used to be a regression-locked sequential fallback.  The inter-stat
    link must carry its spill pad so the second stat's online recurrence
    sees its own neutral element in the lane-padded tail."""
    spec = CHAINS["double_softmax"]
    wide = {"input": (1, 2 ** 21), "output": (1, 2 ** 21)}
    prog = build_chain(spec, wide, mode="fused")
    assert prog.meta["fusion"]["mode"] == "fused"
    assert prog.meta["fusion"]["pattern"] == "streaming"
    assert prog.meta["fusion"]["spills"] == {"h": "output"}
    assert dict(spec.pad_values)["h"] == -3.0e38


def test_resolve_and_build_shared_fallback_policy():
    """The extracted resolve-and-build helper applies the registered
    fallback for the default variant only."""
    from repro.bench import suite
    task = {t.name: t for t in suite()}["softmax"]
    import dataclasses
    long_rows = dataclasses.replace(
        task, shapes={"input": (8, 4 * 1024 * 1024),
                      "output": (8, 4 * 1024 * 1024)})
    art, resolved = resolve_and_build(
        long_rows, PLANNER_REGISTRY["softmax"], "default", None,
        long_rows.shapes, check_shapes=None, verify_against_interp=False)
    assert resolved == "softmax_streaming"
    with pytest.raises(NotImplementedError):
        resolve_and_build(long_rows, PLANNER_REGISTRY["softmax"],
                          "not-default", None, long_rows.shapes,
                          check_shapes=None, verify_against_interp=False)


# ---------------------------------------------------------------------------
# Cache fingerprints
# ---------------------------------------------------------------------------

def test_fused_artifacts_get_distinct_cache_keys(tasks, tmp_path):
    cache = ArtifactCache(str(tmp_path))
    task = tasks["bias_gelu"]
    k_seq = cache.key_for(task, Knobs(), variant="default")
    k_fused = cache.key_for(task, Knobs(), variant="fused")
    assert k_seq != k_fused
    # a plain task with the same tensors but no chain attrs keys differently
    import dataclasses
    plain = dataclasses.replace(task, attrs={})
    assert cache.key_for(plain, Knobs()) != cache.key_for(task, Knobs())


def test_fused_artifact_roundtrips_through_cache(tasks, tmp_path):
    """generate(tune=True) caches the fused winner; the second call serves
    the fused program from the cache with no search and no lowering."""
    from repro.core.lowering.pipeline import PIPELINE_COUNTERS
    cache = ArtifactCache(str(tmp_path))
    task = tasks["bias_gelu"]
    r1 = generate(task, tune=True, tune_budget=6, cache=cache)
    assert r1.pass_ok and r1.tune is not None
    assert r1.tune.best.candidate.variant == "fused"
    assert r1.artifact.program.name.endswith("_fused")
    before = dict(PIPELINE_COUNTERS)
    r2 = generate(task, tune=True, tune_budget=6, cache=cache)
    assert r2.cached and r2.tune is None
    assert r2.artifact.program.name.endswith("_fused")
    assert dict(PIPELINE_COUNTERS) == before


# ---------------------------------------------------------------------------
# Property: fused == sequential composition under the DSL interpreter
# ---------------------------------------------------------------------------

def _random_spec(ops, binary_first):
    stages = []
    prev = "input"
    extra_inputs = []
    for i, op in enumerate(ops):
        out = "output" if i == len(ops) - 1 else f"h{i}"
        if i == 0 and binary_first:
            extra_inputs.append("other")
            stages.append(ChainStage(op if op in ("add", "mul") else "add",
                                     (prev, "other"), out))
        else:
            stages.append(ChainStage(op, (prev,), out))
        prev = out
    return ChainSpec(
        name="prop_chain",
        inputs=tuple([("input", 2)] + [(n, 2) for n in extra_inputs]),
        outputs=("output",),
        stages=tuple(stages))


_ELEMWISE = ["gelu", "silu", "relu", "tanh", "sigmoid", "abs", "square"]


def _property_cases(n=15, seed=20260727):
    """Deterministic random chain generator (hypothesis-style coverage
    without the dependency — the container may not ship hypothesis)."""
    rng = np.random.RandomState(seed)
    for _ in range(n):
        rows = int(rng.randint(1, 13))
        cols = int(rng.randint(4, 401))
        ops = [str(rng.choice(_ELEMWISE))
               for _ in range(int(rng.randint(2, 5)))]
        yield rows, cols, ops, bool(rng.randint(2)), int(rng.randint(2**31))


@pytest.mark.parametrize("rows,cols,ops,binary_first,seed",
                         list(_property_cases()))
def test_fuse_equals_sequential_composition(rows, cols, ops, binary_first,
                                            seed):
    """fuse_programs output == the sequential composition under the DSL
    numpy interpreter, on randomly generated compatible chains (both run
    on the lane-padded GM the programs address)."""
    spec = _random_spec(ops, binary_first)
    cols_p = -(-cols // 128) * 128
    shapes = {t: ((rows, cols) if r == 2 else (cols,))
              for t, r in spec.inputs}
    shapes["output"] = (rows, cols)
    fused = build_chain(spec, shapes, mode="fused")
    seq = build_chain(spec, shapes, mode="sequential")
    assert fused.meta["fusion"]["mode"] == "fused"
    assert seq.meta["fusion"]["mode"] == "sequential"
    # fusion really deleted the link round trip
    n_loads_f = sum(1 for s, _ in A.walk_stmts(fused.kernel.body)
                    if isinstance(s, A.Load))
    n_loads_s = sum(1 for s, _ in A.walk_stmts(seq.kernel.body)
                    if isinstance(s, A.Load))
    assert n_loads_f < n_loads_s

    rng = np.random.RandomState(seed)
    inputs = {t: np.pad(rng.randn(*shapes[t]).astype(np.float32),
                        [(0, 0)] * (len(shapes[t]) - 1)
                        + [(0, cols_p - cols)])
              for t, _ in spec.inputs}
    out_shapes = {"output": (rows, cols_p)}
    got_f = interpret(fused, inputs, out_shapes)["output"]
    got_s = interpret(seq, inputs, out_shapes)["output"]
    np.testing.assert_allclose(got_f[:, :cols], got_s[:, :cols],
                               rtol=0, atol=0)


# ---------------------------------------------------------------------------
# Streaming loop-carry stitching (DESIGN.md §10)
# ---------------------------------------------------------------------------

_STAT_OPS = [None, "softmax", "rmsnorm", "log_softmax"]


def _streaming_cases(n=12, seed=20260728):
    """Deterministic random streaming chains: 0-2 prefix maps, an optional
    loop-carried stat, 0-2 suffix maps (suffix only when a stat exists,
    matching real epilogues)."""
    rng = np.random.RandomState(seed)
    for _ in range(n):
        rows = int(rng.randint(1, 9))
        cols = int(rng.randint(4, 521))
        stat = _STAT_OPS[int(rng.randint(len(_STAT_OPS)))]
        n_pre = int(rng.randint(0, 3))
        n_suf = int(rng.randint(0, 3)) if stat else 0
        if not stat and n_pre < 2:
            n_pre = 2           # pure-map chains need >= 2 stages
        if stat and n_pre + n_suf == 0:
            n_pre = 1           # a lone stat is not a chain
        pre = [str(rng.choice(["add", "mul"])) for _ in range(n_pre)]
        suf = [str(rng.choice(_ELEMWISE)) for _ in range(n_suf)]
        yield rows, cols, stat, tuple(pre), tuple(suf), int(rng.randint(2**31))


def _streaming_spec(stat, pre, suf):
    stages, inputs, prev = [], [("input", 2)], "input"
    for i, op in enumerate(pre):
        vec = f"v{i}"
        inputs.append((vec, 1))
        stages.append(ChainStage(op, (prev, vec), f"p{i}"))
        prev = f"p{i}"
    if stat == "rmsnorm":
        inputs.append(("weight", 1))
        stages.append(ChainStage("rmsnorm", (prev, "weight"), "s0"))
        prev = "s0"
    elif stat in ("softmax", "log_softmax"):
        stages.append(ChainStage(stat, (prev,), "s0"))
        prev = "s0"
    for i, op in enumerate(suf):
        stages.append(ChainStage(op, (prev,), f"e{i}"))
        prev = f"e{i}"
    stages[-1] = ChainStage(stages[-1].op, stages[-1].inputs, "output")
    pads = ()
    if stat in ("softmax", "log_softmax"):
        # neutral-pad chain: every prefix input must keep the computed
        # intermediate at softmax's neutral element in padded columns
        pads = [("input", -3.0e38)]
        pads += [(f"v{i}", 1.0 if op == "mul" else 0.0)
                 for i, op in enumerate(pre)]
        pads = tuple((t, v) for t, v in pads if v != 0.0)
    return ChainSpec(name="sprop", inputs=tuple(inputs),
                     outputs=("output",), stages=tuple(stages),
                     pad_values=pads)


@pytest.mark.parametrize("rows,cols,stat,pre,suf,seed",
                         list(_streaming_cases()))
def test_streaming_fused_equals_sequential(rows, cols, stat, pre, suf, seed):
    """Loop-carry-stitched streaming fusion == the sequential streaming
    composition == the resident fused program, under the DSL interpreter,
    on randomly generated chains (prefix maps / stat recurrence / suffix
    maps)."""
    spec = _streaming_spec(stat, pre, suf)
    shapes = {t: ((rows, cols) if r == 2 else (cols,))
              for t, r in spec.inputs}
    shapes["output"] = (rows, cols)
    fused = build_chain(spec, shapes, mode="fused", pattern="streaming")
    seq = build_chain(spec, shapes, mode="sequential", pattern="streaming")
    ref = build_chain(spec, shapes, mode="fused", pattern="resident")
    assert fused.meta["fusion"]["pattern"] == "streaming"
    if stat:
        # the stat's running scalars survived stitching (loop carry)
        from repro.core.lowering.analysis import declared_scalars
        assert declared_scalars(fused.kernel.body)

    rng = np.random.RandomState(seed)
    if stat == "rmsnorm":
        mk = lambda shp: rng.uniform(0.5, 1.5, shp).astype(np.float32)
    else:
        mk = lambda shp: rng.randn(*shp).astype(np.float32)
    inputs = {t: mk(shapes[t]) for t, _ in spec.inputs}
    out = {"output": (rows, cols)}
    got_r = interpret(ref, _pad_like(ref, inputs, spec),
                      _padded_outs(ref, out))["output"][:, :cols]
    got_f = interpret(fused, _pad_like(fused, inputs, spec),
                      _padded_outs(fused, out))["output"][:, :cols]
    souts = _padded_outs(seq, out)
    for sc in seq.meta.get("scratch_outs", []):
        souts[sc] = souts["output"]
    got_s = interpret(seq, _pad_like(seq, inputs, spec),
                      souts)["output"][:, :cols]
    np.testing.assert_allclose(got_f, got_s, rtol=0, atol=0)
    np.testing.assert_allclose(got_f, got_r, rtol=2e-6, atol=2e-6)


def _pad_like(prog, inputs, spec):
    """Pad inputs exactly as the generated wrapper would (trailing axis to
    the program's pad unit, per-tensor pad value)."""
    from repro.core.dsl.language import eval_host
    shapes = {k: v.shape for k, v in inputs.items()}
    plan = eval_host(prog.host, {**shapes,
                                 **prog.meta.get("task_shapes", {})})
    out = {}
    for t, arr in inputs.items():
        unit = prog.meta["gm_layout"][t]["pad_multiple"]
        m = plan[unit] if isinstance(unit, str) else int(unit)
        padded = -(-arr.shape[-1] // m) * m
        out[t] = np.pad(arr, [(0, 0)] * (arr.ndim - 1)
                        + [(0, padded - arr.shape[-1])],
                        constant_values=spec.pad_value(t))
    return out


def _padded_outs(prog, outs):
    from repro.core.dsl.language import eval_host
    plan = prog.meta["plan"]
    res = {}
    for t, shp in outs.items():
        unit = prog.meta["gm_layout"][t]["pad_multiple"]
        m = plan[unit] if isinstance(unit, str) else int(unit)
        res[t] = (*shp[:-1], -(-shp[-1] // m) * m)
    return res


def test_streaming_fused_spills_once_not_per_pass(tasks):
    """The loop-carry stitcher spills the producer chain's result through
    the output tensor ONCE (first softmax pass) instead of recomputing it
    per pass; with the 2-pass ONLINE softmax (DESIGN.md §12) there is only
    ONE later pass, so the spill is re-read once — producer inputs read
    once, scores round-trip once, total modeled traffic 6N for a chain
    whose eager baseline moves ~6N (the at-eager acceptance bar)."""
    task = tasks["attn_scores"]
    prog = _build(task, "fused", task.shapes)
    assert prog.meta["fusion"]["pattern"] == "streaming"
    assert prog.meta["fusion"]["spills"] == {"h2": "output"}
    loads = [s for s, _ in A.walk_stmts(prog.kernel.body)
             if isinstance(s, A.Load)]
    stores = [s for s, _ in A.walk_stmts(prog.kernel.body)
              if isinstance(s, A.Store)]
    by_tensor = {}
    for ld in loads:
        by_tensor[ld.tensor] = by_tensor.get(ld.tensor, 0) + 1
    # producer inputs read once (pass 1); spilled scores re-read ONCE
    assert by_tensor == {"input": 1, "scale": 1, "mask": 1, "output": 1}
    assert len(stores) == 2          # the spill + the final output


def test_attn_scores_models_at_or_above_eager_softmax(tasks):
    """Acceptance bar for the 2-pass online softmax: the fused attn_scores
    chain — whose eager baseline prices softmax as a SINGLE kernel — no
    longer models below eager.  (The 3-pass Fig.-2 form moved 7N bytes
    against eager's ~6N; the online form moves 6N.)"""
    task = tasks["attn_scores"]
    prog = _build(task, "fused", task.shapes)
    assert fast_ratio(task, prog) >= 1.0


# ---------------------------------------------------------------------------
# DAG chains: live-range-correct sequential baselines
# ---------------------------------------------------------------------------

def test_dag_sequential_routes_conflicting_links_through_scratch(tasks):
    """swiglu_proj's merge keeps two links live at once: one can reuse the
    output tensor, the other must get a dedicated scratch GM tensor —
    which the entry point allocates but never returns."""
    task = tasks["swiglu_proj"]
    seq = _build(task, "default", task.check_shapes)
    assert seq.meta["scratch_outs"] == ["scratch0"]
    route = seq.meta["fusion"]["route"]
    assert sorted(route.values()) == ["output", "scratch0"]
    # lowered end-to-end: entry returns ONLY the declared output and
    # matches the composed reference
    art = generate_with_feedback(
        lambda kn: _build(task, "default", task.check_shapes),
        Knobs(), check_shapes=None, verify_against_interp=False)
    chk = check_artifact_numerics(task, art)
    assert chk.pass_ok, chk.error
    import numpy as np_
    arrays = [np_.random.RandomState(0).randn(*task.check_shapes[tp.name])
              .astype(np_.float32) for tp in task.input_specs]
    res = art.entry(*arrays, interpret=True)
    assert not isinstance(res, (tuple, list))      # scratch not returned


def test_linear_chain_sequential_needs_no_scratch(tasks):
    """Live-range analysis reuses one output tensor for a linear chain's
    links (non-overlapping ranges) — scratch only appears at DAG merges."""
    task = tasks["attn_scores"]
    seq = _build(task, "default", task.check_shapes)
    assert "scratch_outs" not in seq.meta
    route = seq.meta["fusion"]["route"]
    assert set(route.values()) == {"output"}       # h1 and h2 share it


def test_dag_fused_loads_shared_input_once(tasks):
    """The fused DAG kernel deduplicates the shared producer input: one
    load feeds both the gate and up branches."""
    task = tasks["swiglu_proj"]
    fused = _build(task, "fused", task.shapes)
    loads = [s for s, _ in A.walk_stmts(fused.kernel.body)
             if isinstance(s, A.Load)]
    assert sorted(ld.tensor for ld in loads) == ["gate_scale", "input",
                                                 "up_scale"]


# ---------------------------------------------------------------------------
# Differential property suite (DESIGN.md §11): for EVERY registered chain
# — declared fixture or jaxpr-extracted — fused ≡ sequential ≡ composed
# float64 reference on seeded-random inputs at odd, non-lane-aligned
# shapes, across the resident and streaming patterns.  Parametrizing over
# sorted(CHAINS) at collection time IS the no-untested-chain gate: a chain
# registered without a differentially-testable stage vocabulary fails
# here, and CI runs this file on every push.
# ---------------------------------------------------------------------------

import zlib

from repro.bench.tasks import (_ACT_REFS, _MATH_REFS, _log_softmax,
                               _softmax)
from repro.core.fusion import CHAINS


def _stage_ref64(op, args, attrs):
    """Float64 reference for one chain stage (the DSL-independent oracle
    the differential test composes along spec.stages).  Norm stages honor
    the chain's traced eps attr (DESIGN.md §12)."""
    a64 = [np.asarray(a, np.float64) for a in args]
    if op == "add":
        return a64[0] + a64[1]
    if op == "sub":
        return a64[0] - a64[1]
    if op == "mul":
        return a64[0] * a64[1]
    if op == "swiglu":
        return _ACT_REFS["silu"](a64[0]) * a64[1]
    if op == "softmax":
        return _softmax(a64[0])
    if op == "log_softmax":
        return _log_softmax(a64[0])
    if op == "rmsnorm":
        eps = float(attrs.get("eps", 1e-6))
        rms = np.sqrt((a64[0] * a64[0]).mean(-1, keepdims=True) + eps)
        return a64[0] / rms * a64[1]
    if op == "layernorm":
        eps = float(attrs.get("eps", 1e-5))
        mu = a64[0].mean(-1, keepdims=True)
        var = ((a64[0] - mu) ** 2).mean(-1, keepdims=True)
        return (a64[0] - mu) / np.sqrt(var + eps) * a64[1] + a64[2]
    if op == "square":
        return a64[0] * a64[0]
    if op == "abs":
        return np.abs(a64[0])
    if op == "neg":
        return -a64[0]
    if op == "scale":
        return a64[0] * float(attrs["scale"])
    if op == "smul":
        # dynamic scalar: a 1-element GM tensor multiplied across the row
        return a64[0] * a64[1].reshape(())
    if op == "rmsnorm_bwd":
        eps = float(attrs.get("eps", 1e-6))
        x, w, g = a64
        n = g * w
        inv = 1.0 / np.sqrt((x * x).mean(-1, keepdims=True) + eps)
        s = (x * n).sum(-1, keepdims=True)
        return n * inv - x * s * inv ** 3 / x.shape[-1]
    if op == "softmax_bwd":
        z, g = a64
        y = _softmax(z)
        return y * (g - (g * y).sum(-1, keepdims=True))
    if op == "log_softmax_bwd":
        z, g = a64
        return g - _softmax(z) * g.sum(-1, keepdims=True)
    if op == "matmul":
        return a64[0] @ a64[1]
    if op == "matmul_t":
        return a64[0] @ a64[1].T
    if op in _ACT_REFS:
        return _ACT_REFS[op](a64[0])
    if op in _MATH_REFS:
        return _MATH_REFS[op](a64[0])
    raise AssertionError(
        f"no float64 reference for stage op '{op}': every registered "
        f"chain must be coverable by the differential suite")


def _compose_ref64(spec, inputs):
    env = {k: np.asarray(v, np.float64) for k, v in inputs.items()}
    for st in spec.stages:
        # per-stage attr resolution: a ``key@<stage output>`` qualified
        # attr (conflicting values across stages) overrides the plain key
        # for exactly its own stage — mirroring chain._stage_attrs
        attrs = {k: v for k, v in spec.attrs if "@" not in k}
        for k, v in spec.attrs:
            if k.endswith(f"@{st.output}"):
                attrs[k.split("@", 1)[0]] = v
        env[st.output] = _stage_ref64(st.op, [env[t] for t in st.inputs],
                                      attrs)
    return {t: env[t] for t in spec.outputs}


def _matmul_chain_shapes(spec, rows, cols, d=10):
    """Forward shape assignment for chains with contraction stages: the
    primary operand of a matmul_t gets (rows, d), its weight side
    (cols, d); the row-tensor shape then flows through map/stat stages
    and a trailing matmul contracts back to (rows, d).  d is odd and
    non-lane on purpose."""
    declared = dict(spec.inputs)
    cur = {}

    def setin(t, shp):
        cur.setdefault(t, shp)

    for st in spec.stages:
        if st.op == "matmul_t":
            setin(st.inputs[0], (rows, d))
            setin(st.inputs[1], (cols, d))
            cur[st.output] = (cur[st.inputs[0]][0], cur[st.inputs[1]][0])
        elif st.op == "matmul":
            r = cur.get(st.inputs[0], (rows, cols))
            setin(st.inputs[0], r)
            setin(st.inputs[1], (r[1], d))
            cur[st.output] = (r[0], d)
        else:
            r = cur.get(st.inputs[0], (rows, cols))
            setin(st.inputs[0], r)
            for t in st.inputs[1:]:
                setin(t, r if declared.get(t, 2) == 2 else (r[-1],))
            cur[st.output] = r
    return {t: cur[t] for t, _ in spec.inputs}


def _diff_inputs(spec, rows, cols, seed):
    """Seeded random inputs; rank-1 operands of stat stages (rmsnorm
    weights) draw positive so the f64 oracle stays well-conditioned."""
    rng = np.random.RandomState(seed)
    weights = {st.inputs[1] for st in spec.stages
               if st.op == "rmsnorm" and len(st.inputs) > 1}
    if any(st.op in ("matmul", "matmul_t") for st in spec.stages):
        shapes = _matmul_chain_shapes(spec, rows, cols)
    else:
        # rank-0 chain inputs (extracted dynamic scalars, e.g. the mhc
        # mixing weights) materialize as 1-element GM tensors
        shapes = {t: ((rows, cols) if r == 2 else
                      (cols,) if r == 1 else (1,))
                  for t, r in spec.inputs}
    inputs = {}
    for t, _r in spec.inputs:
        if t in weights:
            inputs[t] = rng.uniform(0.5, 1.5, shapes[t]).astype(np.float32)
        else:
            inputs[t] = rng.randn(*shapes[t]).astype(np.float32)
    return shapes, inputs


def _run_chain_prog(prog, spec, inputs, out_shapes):
    souts = _padded_outs(prog, out_shapes)
    for sc in prog.meta.get("scratch_outs", []):
        # scratch GM shapes come from the program itself (a spilled link
        # need not match any user-visible output — e.g. the flash score
        # row vs the (rows, head_dim) output)
        souts[sc] = _padded_outs(
            prog, {sc: prog.meta["task_shapes"][sc]})[sc]
    res = interpret(prog, _pad_like(prog, inputs, spec), souts)
    return {t: res[t] for t in spec.outputs}


def _chain_differential(chain, rows, cols, seed,
                        patterns=("resident", "streaming")):
    """Build every available (pattern, mode) program for the chain and
    check fused ≡ sequential (bit-exact within a pattern) and everything ≡
    the composed f64 reference.  Returns the built keys."""
    spec = CHAINS[chain]
    shapes, inputs = _diff_inputs(spec, rows, cols, seed)
    ref = _compose_ref64(spec, inputs)
    full = spec.chain_shapes(shapes)
    out_shapes = {t: full[t] for t in spec.outputs}
    built = {}
    for pattern in patterns:
        for mode in ("fused", "sequential"):
            try:
                prog = build_chain(spec, shapes, mode=mode, name=None,
                                   pattern=pattern)
            except (NotImplementedError, FusionError):
                continue   # pattern structurally unsupported at this shape
            built[(pattern, mode)] = _run_chain_prog(prog, spec, inputs,
                                                     out_shapes)
    for (pattern, mode), outs in built.items():
        for t in spec.outputs:
            np.testing.assert_allclose(
                outs[t][:ref[t].shape[0], :ref[t].shape[1]], ref[t],
                rtol=3e-4, atol=2e-5,
                err_msg=f"{chain} {pattern}/{mode} output '{t}' diverges "
                        f"from the composed f64 reference")
    for pattern in patterns:
        f, s = built.get((pattern, "fused")), built.get((pattern,
                                                         "sequential"))
        if f is not None and s is not None:
            for t in spec.outputs:
                np.testing.assert_allclose(
                    f[t], s[t], rtol=0, atol=0,
                    err_msg=f"{chain} {pattern}: fused != sequential")
    return built


@pytest.mark.parametrize("rows,cols", [(5, 97), (7, 331)])
@pytest.mark.parametrize("chain", sorted(CHAINS))
def test_differential_fused_sequential_f64(chain, rows, cols):
    seed = zlib.crc32(f"{chain}-{rows}-{cols}".encode()) % (2 ** 31)
    built = _chain_differential(chain, rows, cols, seed)
    assert any(m == "fused" for _, m in built), (chain, "no fused build")
    assert any(m == "sequential" for _, m in built), (chain,
                                                      "no sequential build")


def test_every_registered_chain_has_differential_coverage():
    """The no-untested-chain gate, stated directly: the parametrization
    above covers set(CHAINS) exactly, every registered chain's stage
    vocabulary is evaluable by the f64 oracle, and every (chain, storage
    dtype) the structure admits has a quantized differential row — a new
    chain (or a newly eligible dtype) is picked up at collection time, not
    by hand-listing."""
    for name, spec in CHAINS.items():
        shapes, inputs = _diff_inputs(spec, 3, 65, 0)
        outs = _compose_ref64(spec, inputs)
        assert set(outs) == set(spec.outputs), name
    want_quant = {(c, dt) for c in CHAINS for dt in chain_storage_dtypes(c)}
    assert set(_QUANT_ROWS) == want_quant
    assert any(dt == "int8" for _, dt in _QUANT_ROWS)
    # matmul adjacency forbids quantized storage on flash_attention
    assert not any(c == "flash_attention" for c, _ in _QUANT_ROWS)


# ---------------------------------------------------------------------------
# Quantized-storage differential rows (DESIGN.md §17): every (chain, dtype)
# the structure admits, derived from sorted(CHAINS) — never hand-listed
# ---------------------------------------------------------------------------

from repro.core.fusion.chain import Q_VERIFY_TOL, chain_storage_dtypes

_QUANT_ROWS = [(chain, dt) for chain in sorted(CHAINS)
               for dt in chain_storage_dtypes(chain)]


def _np_quantize(a, inv, dt):
    """Bitwise the entry wrapper's jnp quantizer (pipeline.py interp
    verify uses the identical numpy form)."""
    a = np.asarray(a, np.float32)
    if dt == "int8":
        return np.clip(np.floor(a * np.float32(inv) + np.float32(0.5)),
                       -127.0, 127.0).astype(np.int8)
    import ml_dtypes
    return np.clip(a * np.float32(inv),
                   -448.0, 448.0).astype(ml_dtypes.float8_e4m3fn)


@pytest.mark.parametrize("chain,dt", _QUANT_ROWS,
                         ids=[f"{c}-{d}" for c, d in _QUANT_ROWS])
def test_differential_quantized_storage(chain, dt):
    """Quantized chains, differentially: for every admitted (chain,
    storage dtype) — fused ≡ sequential BIT-EXACT on the raw storage
    codes per pattern (the whole point of deterministic quantizers and
    fp8's boundary-only rule), and every dequantized output within the
    documented dtype-derived tolerance of the composed f64 oracle."""
    rows, cols = 5, 97
    seed = zlib.crc32(f"q-{chain}-{dt}".encode()) % (2 ** 31)
    spec = CHAINS[chain]
    shapes, inputs = _diff_inputs(spec, rows, cols, seed)
    ref = _compose_ref64(spec, inputs)
    full = spec.chain_shapes(shapes)
    out_shapes = {t: full[t] for t in spec.outputs}
    built = {}
    for pattern in ("resident", "streaming"):
        for mode in ("fused", "sequential"):
            try:
                prog = build_chain(spec, shapes, mode=mode, name=None,
                                   pattern=pattern, storage_dtype=dt)
            except (NotImplementedError, FusionError):
                continue   # pattern structurally unsupported at this shape
            quant = prog.meta.get("quant") or {}
            assert quant.get("dtype") == dt, \
                f"{chain} {pattern}/{mode}: quant meta missing"
            qin, qout = quant.get("in", {}), quant.get("out", {})
            ins = {t: (_np_quantize(v, qin[t]["inv"], dt) if t in qin
                       else v) for t, v in inputs.items()}
            raw = _run_chain_prog(prog, spec, ins, out_shapes)
            deq = {t: (np.asarray(raw[t], np.float64)
                       * float(qout[t]["scale"]) if t in qout
                       else np.asarray(raw[t], np.float64))
                   for t in spec.outputs}
            built[(pattern, mode)] = (raw, deq, set(qout))
    assert any(m == "fused" for _, m in built), (chain, dt, "no fused")
    assert any(m == "sequential" for _, m in built), (chain, dt,
                                                      "no sequential")
    # at least one chain OUTPUT actually lives at the narrow dtype
    # somewhere (otherwise the row tests nothing)
    assert any(qo for _, (_, _, qo) in built.items()), (chain, dt)
    rtol, atol = Q_VERIFY_TOL[dt]
    for (pattern, mode), (_raw, deq, _qo) in built.items():
        for t in spec.outputs:
            g = deq[t][:ref[t].shape[0], :ref[t].shape[1]]
            assert np.allclose(g, ref[t], rtol=rtol, atol=atol), \
                (f"{chain}[{dt}] {pattern}/{mode} output '{t}' diverges "
                 f"from the f64 oracle beyond the documented tolerance "
                 f"(max abs err {np.max(np.abs(g - ref[t])):.4g})")
    for pattern in ("resident", "streaming"):
        f = built.get((pattern, "fused"))
        s = built.get((pattern, "sequential"))
        if f is not None and s is not None:
            for t in spec.outputs:
                np.testing.assert_array_equal(
                    np.asarray(f[0][t]).view(np.uint8),
                    np.asarray(s[0][t]).view(np.uint8),
                    err_msg=f"{chain}[{dt}] {pattern}: fused != "
                            f"sequential (storage codes must be bit-exact)")


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as hst
    _HAVE_HYPOTHESIS = True
except ImportError:          # container without hypothesis: the seeded
    _HAVE_HYPOTHESIS = False  # sweep above still gates every chain

if _HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(chain=hst.sampled_from(sorted(CHAINS)),
           rows=hst.integers(min_value=1, max_value=9),
           cols=hst.integers(min_value=3, max_value=400),
           seed=hst.integers(min_value=0, max_value=2 ** 31 - 1))
    def test_differential_property_hypothesis(chain, rows, cols, seed):
        """Hypothesis-driven differential property: arbitrary odd shapes
        and seeds, same fused ≡ sequential ≡ f64 oracle."""
        _chain_differential(chain, rows, cols, seed)


# ---------------------------------------------------------------------------
# Multi-stat chains: softmax -> softmax (formerly regression-locked to
# refuse at proposal / fall back to sequential — DESIGN.md §12)
# ---------------------------------------------------------------------------

def test_multi_stat_softmax_softmax_extracts_and_proposes():
    """Flipped lock #1: the double-softmax chain now EXTRACTS and
    PROPOSES.  The outer softmax's neutral-pad requirement on the inner
    softmax's output is absorbed as a per-stat spill pad (the inner
    stage's output pass re-blends its lane-padded tail to -3e38) instead
    of refusing the whole chain."""
    import jax
    from repro.core.fusion import extract_chains
    (spec,) = extract_chains(
        lambda x: jax.nn.softmax(jax.nn.softmax(x, axis=-1), axis=-1),
        (("input", (4, 64)),), name="double_softmax")
    assert [st.op for st in spec.stages] == ["softmax", "softmax"]
    assert dict(spec.pad_values) == {"input": -3.0e38, "h": -3.0e38}
    # and the registered chain (from the model workload library) is the
    # same structure
    from repro.core.fusion.propose import chain_fingerprint
    assert chain_fingerprint(spec) == \
        chain_fingerprint(CHAINS["double_softmax"])


def test_multi_stat_fuses_streaming_with_per_stat_spill():
    """Flipped lock #2: at streaming scale the softmax->softmax chain
    loop-carry stitches FUSED (each stat keeps its own online (m, d)
    recurrence; the inter-stat link spills once through the output), and
    its numerics hold at NON-lane-aligned columns — the shape class the
    old sequential fallback was pinned to avoid, because the unblended
    inner softmax output was pad-unsound."""
    spec = CHAINS["double_softmax"]
    wide = {"input": (1, 2 ** 21), "output": (1, 2 ** 21)}
    prog = build_fused(spec, wide, fallback=False)
    assert prog.meta["fusion"]["mode"] == "fused"
    assert prog.meta["fusion"]["pattern"] == "streaming"
    assert prog.meta["fusion"]["spills"] == {"h": "output"}
    # numerics at odd, NON-lane-aligned columns, both patterns and modes
    rows, cols = 4, 331
    shapes = {"input": (rows, cols), "output": (rows, cols)}
    rng = np.random.RandomState(7)
    x = rng.randn(rows, cols).astype(np.float32)
    want = _softmax(_softmax(x.astype(np.float64)))
    for pattern in ("resident", "streaming"):
        for mode in ("sequential", "fused"):
            prog = build_chain(spec, shapes, mode=mode, pattern=pattern)
            got = _run_chain_prog(prog, spec, {"input": x},
                                  {"output": (rows, cols)})["output"]
            np.testing.assert_allclose(got[:, :cols], want, rtol=3e-4,
                                       atol=2e-5,
                                       err_msg=f"{pattern}/{mode}")


def test_multi_stat_chain_beats_sequential_baseline(tasks, tmp_path):
    """Acceptance bar: extracted softmax->softmax proposes, tuner-fuses
    (no ProposeError anywhere in the path) and models faster than its
    sequential baseline — the fused schedule moves 5N bytes against the
    sequential 6N."""
    task = tasks["double_softmax"]
    tr = tune(task, budget=6, cache=str(tmp_path))
    assert tr.best.ok, tr.best.error
    assert tr.best.candidate.variant == "fused"
    assert tr.improvement > 1.1, tr.improvement
    prog = _build(task, "fused", task.shapes)
    assert prog.meta["fusion"]["pattern"] == "streaming"


def test_new_extraction_coverage_chains_tuner_fuse(tasks, tmp_path):
    """log_softmax and layernorm composites (formerly barrier.<prim>) are
    extracted, registered and tuner-fused: the LM-head bias+log_softmax
    epilogue and the post-LN residual block."""
    for name in ("bias_log_softmax", "add_layernorm"):
        tr = tune(tasks[name], budget=6, cache=str(tmp_path / name))
        assert tr.best.ok, (name, tr.best.error)
        assert tr.best.candidate.variant == "fused", name
        assert tr.improvement >= 1.3, (name, tr.improvement)


# ---------------------------------------------------------------------------
# Online-softmax edge numerics (DESIGN.md §12): pad sentinels, fully
# masked rows, single-tile degeneracy
# ---------------------------------------------------------------------------

def test_online_softmax_rows_with_pad_sentinel_values():
    """Rows CONTAINING -3e38 sentinel values (the pad value appearing as
    data): exp(-3e38 - m) underflows to exactly 0, so those positions drop
    out of the denominator — matching the f64 oracle."""
    spec = CHAINS["mul_softmax"]
    rows, cols = 3, 300
    shapes = {"input": (rows, cols), "scale": (cols,),
              "output": (rows, cols)}
    rng = np.random.RandomState(11)
    x = rng.randn(rows, cols).astype(np.float32)
    x[0, 5] = x[0, 200] = x[2, 0] = -3.0e38
    s = np.ones(cols, np.float32)
    want = _softmax(np.float64(x) * np.float64(s))
    for pattern in ("resident", "streaming"):
        for mode in ("fused", "sequential"):
            prog = build_chain(spec, shapes, mode=mode, pattern=pattern)
            got = _run_chain_prog(prog, spec, {"input": x, "scale": s},
                                  {"output": (rows, cols)})["output"]
            np.testing.assert_allclose(got[:, :cols], want, rtol=3e-4,
                                       atol=2e-5,
                                       err_msg=f"{pattern}/{mode}")


def test_online_softmax_fully_masked_rows_are_nan_like_the_oracle():
    """A fully -inf row has no defined softmax (0/0): the f64 oracle
    yields NaN, and every generated form must agree — the online
    recurrence's running denominator stays 0 rather than silently
    normalizing garbage."""
    spec = CHAINS["double_softmax"]
    rows, cols = 2, 256
    shapes = {"input": (rows, cols), "output": (rows, cols)}
    x = np.random.RandomState(5).randn(rows, cols).astype(np.float32)
    x[1, :] = -np.inf
    ref = _softmax(_softmax(np.float64(x)))
    assert np.isnan(ref[1]).all() and np.isfinite(ref[0]).all()
    for pattern in ("resident", "streaming"):
        for mode in ("fused", "sequential"):
            prog = build_chain(spec, shapes, mode=mode, pattern=pattern)
            got = _run_chain_prog(prog, spec, {"input": x},
                                  {"output": (rows, cols)})["output"]
            assert np.isnan(got[1, :cols]).all(), f"{pattern}/{mode}"
            np.testing.assert_allclose(got[0, :cols], ref[0], rtol=3e-4,
                                       atol=2e-5,
                                       err_msg=f"{pattern}/{mode}")


def test_online_softmax_single_tile_degenerates_bit_exactly():
    """When the whole row fits one tile, the online recurrence reduces to
    m = max(tile), d = 0 * exp(...) + sum(exp(tile - m)) — bit-identical
    to the resident reduction, so streaming and resident programs must
    agree EXACTLY (cols == one lane-aligned tile: identical padding)."""
    spec = CHAINS["mul_softmax"]
    rows, cols = 4, 256
    shapes = {"input": (rows, cols), "scale": (cols,),
              "output": (rows, cols)}
    rng = np.random.RandomState(9)
    x = rng.randn(rows, cols).astype(np.float32)
    s = rng.uniform(0.5, 1.5, cols).astype(np.float32)
    stream = build_chain(spec, shapes, mode="fused", pattern="streaming")
    assert stream.meta["plan"]["n_tiles"] == 1
    resident = build_chain(spec, shapes, mode="fused", pattern="resident")
    got_s = _run_chain_prog(stream, spec, {"input": x, "scale": s},
                            {"output": (rows, cols)})["output"]
    got_r = _run_chain_prog(resident, spec, {"input": x, "scale": s},
                            {"output": (rows, cols)})["output"]
    np.testing.assert_array_equal(got_s[:, :cols], got_r[:, :cols])


# ---------------------------------------------------------------------------
# Flash-attention shape zoo (DESIGN.md §13): the chain extracted THROUGH
# both matmul barriers, differentially checked against the framework's
# attention reference per (batch, head) slice — MHA/GQA/MQA head mappings,
# odd non-lane head dims, and resident -> streaming sequence lengths.
# ---------------------------------------------------------------------------

_FLASH_ZOO = [
    # (B, Sq, Skv, Hq, Hkv, D)
    (1, 4, 4, 1, 1, 16),        # single head, square, trace head dim
    (2, 5, 5, 4, 2, 16),        # GQA 2:1, odd seq
    (1, 3, 33, 4, 1, 10),       # MQA, odd non-lane head dim, Skv > Sq
    (1, 6, 200, 2, 2, 12),      # long KV, kv_heads == q_heads
]


def _flash_causal_mask(Sq, Skv):
    # bottom-right-aligned causal mask, the chain's -3e38 sentinel idiom
    return np.triu(np.full((Sq, Skv), -3.0e38, np.float32), 1 + Skv - Sq)


def _flash_case_programs(Sq, Skv, D):
    spec = CHAINS["flash_attention"]
    shapes = {"q": (Sq, D), "k": (Skv, D), "mask": (Sq, Skv),
              "v": (Skv, D)}
    progs = {}
    for pattern in ("resident", "streaming"):
        for mode in ("fused", "sequential"):
            try:
                progs[(pattern, mode)] = build_chain(
                    spec, shapes, mode=mode, pattern=pattern)
            except (NotImplementedError, FusionError):
                continue
    return spec, progs


@pytest.mark.parametrize("case", _FLASH_ZOO)
def test_flash_zoo_matches_attention_reference_per_head(case):
    """Every buildable (pattern, mode) flash program reproduces
    mha_reference on each (batch, head) slice, with the GQA kv-head
    mapping h // (Hq // Hkv) and the causal additive mask."""
    from repro.kernels.flash_attention.ref import mha_reference
    B, Sq, Skv, Hq, Hkv, D = case
    group = Hq // Hkv
    rng = np.random.RandomState(zlib.crc32(repr(case).encode()) % 2**31)
    q = rng.randn(B, Sq, Hq, D).astype(np.float32) * 0.5
    k = rng.randn(B, Skv, Hkv, D).astype(np.float32) * 0.5
    v = rng.randn(B, Skv, Hkv, D).astype(np.float32) * 0.5
    # baked trace scale, passed explicitly so the oracle computes the
    # same math for every head dim in the zoo
    ref = np.asarray(mha_reference(q, k, v, causal=True, sm_scale=0.25))
    mask = _flash_causal_mask(Sq, Skv)
    spec, progs = _flash_case_programs(Sq, Skv, D)
    assert any(m == "fused" for _, m in progs), "no fused flash build"
    for (pattern, mode), prog in progs.items():
        for b in range(B):
            for h in range(Hq):
                ins = {"q": q[b, :, h, :], "k": k[b, :, h // group, :],
                       "mask": mask, "v": v[b, :, h // group, :]}
                got = _run_chain_prog(prog, spec, ins,
                                      {"output": (Sq, D)})["output"]
                np.testing.assert_allclose(
                    got[:Sq, :D], ref[b, :, h, :], rtol=2e-6, atol=2e-6,
                    err_msg=f"{case} {pattern}/{mode} head ({b},{h})")


def test_flash_streaming_multi_tile_matches_reference():
    """A KV extent beyond one tile: the streaming fused program must run
    its online (m, d) carry across MULTIPLE tiles (n_tiles > 1) and still
    match the attention reference."""
    from repro.kernels.flash_attention.ref import mha_reference
    Sq, Skv, D = 4, 9000, 16
    spec = CHAINS["flash_attention"]
    shapes = {"q": (Sq, D), "k": (Skv, D), "mask": (Sq, Skv),
              "v": (Skv, D)}
    prog = build_chain(spec, shapes, mode="fused", pattern="streaming")
    # a stream width differing from the primary's output columns carries a
    # padded-width suffix in the merged plan (n_tiles_<w>)
    (n_tiles,) = [v for k, v in prog.meta["plan"].items()
                  if k.startswith("n_tiles")]
    assert n_tiles > 1
    rng = np.random.RandomState(8)
    q2 = rng.randn(1, Sq, 1, D).astype(np.float32) * 0.5
    k2 = rng.randn(1, Skv, 1, D).astype(np.float32) * 0.5
    v2 = rng.randn(1, Skv, 1, D).astype(np.float32) * 0.5
    ref = np.asarray(mha_reference(q2, k2, v2, causal=True,
                                   sm_scale=0.25))[0, :, 0, :]
    got = _flash_run(prog, spec, q2[0, :, 0, :], k2[0, :, 0, :],
                     _flash_causal_mask(Sq, Skv), v2[0, :, 0, :])
    np.testing.assert_allclose(got, ref, rtol=2e-6, atol=2e-6)


# ---------------------------------------------------------------------------
# Flash edge numerics (DESIGN.md §13): mask sentinels through the online
# rescale, fully-masked rows, single-tile degeneration
# ---------------------------------------------------------------------------

def _flash_run(prog, spec, q2, k2, mask, v2):
    ins = {"q": q2, "k": k2, "mask": mask, "v": v2}
    Sq, D = q2.shape
    return _run_chain_prog(prog, spec, ins,
                           {"output": (Sq, D)})["output"][:Sq, :D]


def test_flash_fully_masked_rows_match_f64_oracle():
    """A row whose keys are ALL masked.  With the finite -3e38 sentinel
    every lane (real or padded) carries the same score, so the row
    degenerates to a pad-dependent uniform average: the contract is
    FINITE output with every live row untouched — not a specific value.
    With a true -inf mask both the f64 oracle and the chain produce NaN
    (0/0) — the chain may not invent a finite answer."""
    Sq, Skv, D = 4, 33, 10
    rng = np.random.RandomState(5)
    q2 = rng.randn(Sq, D).astype(np.float32)
    k2 = rng.randn(Skv, D).astype(np.float32)
    v2 = rng.randn(Skv, D).astype(np.float32)
    spec, progs = _flash_case_programs(Sq, Skv, D)

    mask = np.zeros((Sq, Skv), np.float32)
    mask[1, :] = -3.0e38                     # row 1 fully masked, finite
    s64 = (q2.astype(np.float64) @ k2.astype(np.float64).T * 0.25
           + mask.astype(np.float64))
    p64 = np.exp(s64 - s64.max(-1, keepdims=True))
    ref = (p64 / p64.sum(-1, keepdims=True)) @ v2.astype(np.float64)
    live = [0, 2, 3]
    for key, prog in progs.items():
        got = _flash_run(prog, spec, q2, k2, mask, v2)
        assert np.isfinite(got).all(), key   # sentinel stays NaN-free
        np.testing.assert_allclose(got[live], ref[live], rtol=3e-4,
                                   atol=2e-5, err_msg=str(key))

    mask_inf = mask.copy()
    mask_inf[1, :] = -np.inf                 # true -inf: NaN contract
    s64 = (q2.astype(np.float64) @ k2.astype(np.float64).T * 0.25
           + mask_inf.astype(np.float64))
    with np.errstate(invalid="ignore"):
        p64 = np.exp(s64 - s64.max(-1, keepdims=True))
        ref_inf = (p64 / p64.sum(-1, keepdims=True)) \
            @ v2.astype(np.float64)
    assert np.isnan(ref_inf[1]).all()
    for key, prog in progs.items():
        got = _flash_run(prog, spec, q2, k2, mask_inf, v2)
        # NaN like the unpadded oracle, or exact zero where the pad blend
        # (-3e38 on padded lanes) outweighs the -inf reals and the
        # zero-padded v rows absorb all probability mass
        assert np.isnan(got[1]).all() or (got[1] == 0.0).all(), key
        np.testing.assert_allclose(got[live], ref_inf[live], rtol=3e-4,
                                   atol=2e-5, err_msg=str(key))


def test_flash_sentinel_mask_survives_online_rescale():
    """-3e38 masked positions must contribute EXACTLY zero probability
    through the streaming (m, d) rescale — the output equals the oracle
    computed with those keys hard-excluded."""
    Sq, Skv, D = 3, 150, 12
    rng = np.random.RandomState(6)
    q2 = rng.randn(Sq, D).astype(np.float32)
    k2 = rng.randn(Skv, D).astype(np.float32)
    v2 = rng.randn(Skv, D).astype(np.float32)
    keep = rng.rand(Sq, Skv) > 0.4
    keep[:, 0] = True                        # at least one live key/row
    mask = np.where(keep, 0.0, -3.0e38).astype(np.float32)

    s64 = q2.astype(np.float64) @ k2.astype(np.float64).T * 0.25
    s64 = np.where(keep, s64, -np.inf)       # hard exclusion oracle
    p64 = np.exp(s64 - s64.max(-1, keepdims=True))
    ref = (p64 / p64.sum(-1, keepdims=True)) @ v2.astype(np.float64)

    spec, progs = _flash_case_programs(Sq, Skv, D)
    for key, prog in progs.items():
        got = _flash_run(prog, spec, q2, k2, mask, v2)
        np.testing.assert_allclose(got, ref, rtol=3e-4, atol=2e-5,
                                   err_msg=str(key))


def test_flash_single_tile_streaming_degenerates_bit_exactly():
    """One KV tile: the online recurrence collapses to the plain
    reduction, so the streaming and resident fused programs must agree
    bit for bit (lane-aligned columns: identical padding)."""
    Sq, Skv, D = 4, 128, 16
    rng = np.random.RandomState(7)
    q2 = rng.randn(Sq, D).astype(np.float32)
    k2 = rng.randn(Skv, D).astype(np.float32)
    v2 = rng.randn(Skv, D).astype(np.float32)
    mask = _flash_causal_mask(Sq, Skv)
    spec = CHAINS["flash_attention"]
    shapes = {"q": (Sq, D), "k": (Skv, D), "mask": (Sq, Skv),
              "v": (Skv, D)}
    stream = build_chain(spec, shapes, mode="fused", pattern="streaming")
    (n_tiles,) = [v for k, v in stream.meta["plan"].items()
                  if k.startswith("n_tiles")]
    assert n_tiles == 1
    resident = build_chain(spec, shapes, mode="fused", pattern="resident")
    got_s = _flash_run(stream, spec, q2, k2, mask, v2)
    got_r = _flash_run(resident, spec, q2, k2, mask, v2)
    np.testing.assert_array_equal(got_s, got_r)


# ---------------------------------------------------------------------------
# Matmul stage template negative paths (DESIGN.md §13): contractions the
# template must NOT claim stay barriers / refuse — never mis-fuse
# ---------------------------------------------------------------------------

def test_non_row_preserving_dot_general_stays_barrier():
    """Contracting over the ROW axis is not a row-preserving stage shape:
    the eqn must remain a barrier.dot_general, segmenting the graph."""
    import jax
    from repro.core.fusion import extract_graph

    def fn(x, w):
        m = jax.lax.dot_general(x, w, (((0,), (0,)), ((), ())))
        return jax.nn.softmax(m, axis=-1)

    graph = extract_graph(fn, (("x", (8, 64)), ("w", (8, 32))),
                          name="colmm")
    assert any(n.op == "barrier.dot_general" for n in graph.nodes)
    assert not any(n.op in ("matmul", "matmul_t") for n in graph.nodes)


def test_batched_single_free_axis_dot_classifies_matmul_t():
    """bsd,btd->bst is the decode-step QK^T shape (rows contract their
    trailing axis against per-batch-slice key rows): it must classify as
    a matmul_t stage in the rows-on-LHS orientation.  Pre-decode-path,
    first-fit orientation selection picked the rows-on-RHS reading (whose
    weight-free axis lands mid-output) and gave up — single-free-axis
    batched dots fit the template BOTH ways, and the matcher must keep
    trying orientations until one places the weight's free axis last."""
    import jax.numpy as jnp
    from repro.core.fusion import extract_graph

    def fn(q, k):
        s = jnp.einsum("bsd,btd->bst", q, k)
        return jnp.tanh(s)

    graph = extract_graph(fn, (("q", (2, 8, 16)), ("k", (2, 8, 16))),
                          name="batched")
    assert any(n.op == "matmul_t" for n in graph.nodes)
    assert not any(n.op == "barrier.dot_general" for n in graph.nodes)


def test_multi_free_axis_weight_dot_stays_barrier():
    """A weight operand with more than one free axis per batch slice is
    outside the 2-D stage template in every orientation: the contraction
    must stay a barrier, not mis-classify as a matmul stage."""
    import jax.numpy as jnp
    from repro.core.fusion import extract_graph

    def fn(q, k):
        s = jnp.einsum("bsd,btud->bstu", q, k)
        return jnp.tanh(s)

    graph = extract_graph(fn, (("q", (2, 8, 16)), ("k", (2, 4, 3, 16))),
                          name="multifree")
    assert any(n.op == "barrier.dot_general" for n in graph.nodes)
    assert not any(n.op in ("matmul", "matmul_t") for n in graph.nodes)


def test_accumulator_vmem_overflow_refuses():
    """A pv accumulator wider than VMEM can never be carried: the build
    must refuse (NotImplementedError / FusionError) instead of emitting
    an unschedulable kernel."""
    spec = CHAINS["flash_attention"]
    # head dim so large the (D,) f32 accumulator alone exceeds the 8 MiB
    # VMEM budget
    D = 4 * 1024 * 1024
    shapes = {"q": (8, D), "k": (256, D), "mask": (8, 256),
              "v": (256, D)}
    with pytest.raises((NotImplementedError, FusionError)):
        build_chain(spec, shapes, mode="fused")


@pytest.mark.parametrize("rows,cols", [(5, 97), (3, 513)])
def test_layernorm_streaming_template_non_lane_aligned(rows, cols):
    """layernorm has a 2-pass streaming stage template (running sum +
    sum-of-squares carries, E[x^2] - mu^2 variance): a pattern-FORCED
    streaming fused build must succeed — no sequential-fallback refusal —
    and match the composed f64 oracle at non-lane-aligned cols."""
    spec = CHAINS["add_layernorm"]
    shapes, inputs = _diff_inputs(spec, rows, cols, seed=23)
    ref = _compose_ref64(spec, inputs)
    full = spec.chain_shapes(shapes)
    out_shapes = {t: full[t] for t in spec.outputs}
    prog = build_chain(spec, shapes, mode="fused", pattern="streaming")
    assert prog.meta["fusion"]["mode"] == "fused"
    assert prog.meta["fusion"]["pattern"] == "streaming"
    outs = _run_chain_prog(prog, spec, inputs, out_shapes)
    for t in spec.outputs:
        np.testing.assert_allclose(
            outs[t][:ref[t].shape[0], :ref[t].shape[1]], ref[t],
            rtol=3e-4, atol=2e-5,
            err_msg=f"streaming layernorm output '{t}' diverges from "
                    f"the composed f64 reference at ({rows}, {cols})")
    # bit-exact against the sequential streaming form of the same chain
    seq = build_chain(spec, shapes, mode="sequential", pattern="streaming")
    souts = _run_chain_prog(seq, spec, inputs, out_shapes)
    for t in spec.outputs:
        np.testing.assert_allclose(outs[t], souts[t], rtol=0, atol=0)


def test_accumulator_at_chain_head_fuses_streaming():
    """FIXED refusal: an accumulator at the CHAIN HEAD now seeds the
    merged row directly (head-acc mode) — a lone matmul builds in fused
    streaming form and matches the f64 matmul, bit-exact against its
    sequential streaming form."""
    spec = ChainSpec(
        name="lone_matmul", inputs=(("p", 2), ("w", 2)),
        outputs=("output",),
        stages=(ChainStage("matmul", ("p", "w"), "output"),))
    shapes = {"p": (8, 300), "w": (300, 12)}
    rng = np.random.RandomState(11)
    p = rng.randn(8, 300).astype(np.float32)
    w = rng.randn(300, 12).astype(np.float32)
    prog = build_chain(spec, shapes, mode="fused", pattern="streaming")
    assert prog.meta["fusion"]["head_acc"] is True
    got = _run_chain_prog(prog, spec, {"p": p, "w": w},
                          {"output": (8, 12)})["output"][:8, :12]
    np.testing.assert_allclose(
        got, p.astype(np.float64) @ w.astype(np.float64),
        rtol=3e-5, atol=3e-5)
    seq = build_chain(spec, shapes, mode="sequential",
                      pattern="streaming")
    sgot = _run_chain_prog(seq, spec, {"p": p, "w": w},
                           {"output": (8, 12)})["output"][:8, :12]
    np.testing.assert_allclose(got, sgot, rtol=0, atol=0)


def test_head_matmul_epilogue_chain_fuses_streaming():
    """The matmul→epilogue shape the old refusal blocked: the epilogue's
    row body rides along the head accumulator's row visit, the link
    spilling ONCE through the size-compatible chain output, and the fused
    result is bit-exact against the sequential streaming form."""
    spec = ChainSpec(
        name="mm_gelu", inputs=(("p", 2), ("w", 2)),
        outputs=("output",),
        stages=(ChainStage("matmul", ("p", "w"), "h"),
                ChainStage("gelu", ("h",), "output")))
    shapes = {"p": (8, 300), "w": (300, 12)}
    rng = np.random.RandomState(12)
    p = rng.randn(8, 300).astype(np.float32)
    w = rng.randn(300, 12).astype(np.float32)
    prog = build_chain(spec, shapes, mode="fused", pattern="streaming")
    fz = prog.meta["fusion"]
    assert fz["head_acc"] is True
    assert fz["spills"] == {"h": "output"}
    got = _run_chain_prog(prog, spec, {"p": p, "w": w},
                          {"output": (8, 12)})["output"][:8, :12]
    ref = _ACT_REFS["gelu"](p.astype(np.float64) @ w.astype(np.float64))
    np.testing.assert_allclose(got, ref, rtol=3e-4, atol=2e-5)
    seq = build_chain(spec, shapes, mode="sequential", pattern="streaming")
    sgot = _run_chain_prog(seq, spec, {"p": p, "w": w},
                           {"output": (8, 12)})["output"][:8, :12]
    np.testing.assert_allclose(got, sgot, rtol=0, atol=0)


def test_accumulator_behind_map_prefix_still_refuses_streaming_fusion():
    """PRESERVED negative: a map prefix jammed ahead of an accumulator
    has no pass boundary for the row-scope drain — fused streaming must
    still raise FusionError; the sequential streaming form builds and is
    numerically correct."""
    spec = ChainSpec(
        name="scale_mm", inputs=(("p0", 2), ("w", 2)),
        outputs=("output",),
        stages=(ChainStage("scale", ("p0",), "p"),
                ChainStage("matmul", ("p", "w"), "output")),
        attrs=(("scale", 2.0),))
    shapes = {"p0": (8, 300), "w": (300, 12)}
    with pytest.raises(FusionError):
        build_chain(spec, shapes, mode="fused", pattern="streaming")
    seq = build_chain(spec, shapes, mode="sequential",
                      pattern="streaming")
    rng = np.random.RandomState(13)
    p0 = rng.randn(8, 300).astype(np.float32)
    w = rng.randn(300, 12).astype(np.float32)
    got = _run_chain_prog(seq, spec, {"p0": p0, "w": w},
                          {"output": (8, 12)})["output"][:8, :12]
    np.testing.assert_allclose(
        got, (2.0 * p0).astype(np.float64) @ w.astype(np.float64),
        rtol=3e-5, atol=3e-5)
