"""DSL-level kernel fusion (DESIGN.md §9): legality, numerics, VMEM
fallback, tuner discovery, traffic parity and cache fingerprints."""
import numpy as np
import pytest

from repro.bench.model import analyze_program, fast_ratio, _padded_shapes_for
from repro.bench.tasks import fused_suite, fused_task
from repro.core.dsl import ast as A
from repro.core.dsl.interp import interpret
from repro.core.fusion import (CHAINS, ChainSpec, ChainStage, FusionError,
                               build_chain, build_fused)
from repro.core.lowering.pipeline import Knobs, generate_with_feedback
from repro.core.planner import (PLANNER_REGISTRY, check_artifact_numerics,
                                generate, resolve_and_build)
from repro.core.tuning import ArtifactCache, tune, variants_for


@pytest.fixture(scope="module")
def tasks():
    return {t.name: t for t in fused_suite()}


def _build(task, variant, shapes):
    builder = variants_for(task.op)[variant]
    return builder(task, shapes, Knobs())


# ---------------------------------------------------------------------------
# End-to-end numerics: every fused chain verifies in interpreter mode
# ---------------------------------------------------------------------------

def test_fused_tasks_generate_and_verify(tasks):
    """The planner default (unfused sequential / hand-written) passes
    Comp@1 + Pass@1 for every chain task."""
    for task in tasks.values():
        r = generate(task)
        assert r.comp_ok and r.pass_ok, (task.name, r.error)


def test_fused_variant_passes_interpreter_verification(tasks):
    """The FUSED program of every chain matches the composed float64
    reference at check shapes under the Pallas interpreter."""
    for task in tasks.values():
        art = generate_with_feedback(
            lambda kn, t=task: _build(t, "fused", t.check_shapes),
            Knobs(), check_shapes=None, verify_against_interp=False)
        assert art.program.name.endswith("_fused")
        chk = check_artifact_numerics(task, art)
        assert chk.pass_ok, (task.name, chk.error)


def test_fused_handles_non_lane_multiple_columns():
    """Pad-neutrality: the computed intermediate must carry the consumer's
    neutral pad (mul_softmax pads input=-3e38, scale=1.0) so a fused
    reduction stays correct when the trailing dim is padded to the lane."""
    shp = {"input": (8, 100), "scale": (100,), "output": (8, 100)}
    task = fused_task("mul_softmax", shp, shp.copy(),
                      ref=lambda x, s: _softmax64(x, s))
    for variant in ("default", "fused"):
        art = generate_with_feedback(
            lambda kn: _build(task, variant, task.check_shapes),
            Knobs(), check_shapes=None, verify_against_interp=False)
        chk = check_artifact_numerics(task, art)
        assert chk.pass_ok, (variant, chk.error)


def _softmax64(x, s):
    v = np.asarray(x, np.float64) * np.asarray(s, np.float64)
    e = np.exp(v - v.max(-1, keepdims=True))
    return e / e.sum(-1, keepdims=True)


# ---------------------------------------------------------------------------
# Traffic: fused deletes the HBM round trip; add_rmsnorm parity
# ---------------------------------------------------------------------------

def _bytes(task, prog):
    return analyze_program(prog,
                           _padded_shapes_for(prog, task.shapes)).bytes_total


def test_fused_traffic_strictly_below_sequential(tasks):
    for task in tasks.values():
        seq = _build(task, "sequential"
                     if "sequential" in variants_for(task.op) else "default",
                     task.shapes)
        fused = _build(task, "fused", task.shapes)
        assert _bytes(task, fused) < _bytes(task, seq), task.name
        # the fused single-visit program is pipelined-eligible; the
        # sequential GM round trip forces the explicit backend
        from repro.core.lowering.analysis import pipelined_eligible
        assert pipelined_eligible(fused) is not None
        assert pipelined_eligible(seq) is None


def test_auto_fused_add_rmsnorm_matches_handwritten_bytes(tasks):
    """Acceptance bar: the chain auto-derived from add + rmsnorm moves the
    same HBM bytes as the hand-written build_add_rmsnorm (within 5%)."""
    task = tasks["add_rmsnorm"]
    hand = PLANNER_REGISTRY["add_rmsnorm"](task, task.shapes, Knobs())
    auto = _build(task, "fused", task.shapes)
    b_hand, b_auto = _bytes(task, hand), _bytes(task, auto)
    assert abs(b_auto - b_hand) <= 0.05 * b_hand, (b_auto, b_hand)


# ---------------------------------------------------------------------------
# Tuner discovery: fused-vs-unfused is a searchable variant axis
# ---------------------------------------------------------------------------

def test_tuner_discovers_fusion(tasks, tmp_path):
    """Acceptance bar: the hill climb picks the fused variant on its own
    for >= 2 chains, each modeling >= 1.3x the unfused sequential
    baseline."""
    wins = 0
    for name in ("bias_gelu", "mul_softmax", "rmsnorm_swiglu"):
        tr = tune(tasks[name], budget=6, cache=str(tmp_path / name))
        assert tr.best.ok
        if tr.best.candidate.variant == "fused" and tr.improvement >= 1.3:
            wins += 1
    assert wins >= 2, f"only {wins} chains tuned into fusion"


def test_streaming_is_a_searchable_variant(tmp_path):
    """ROADMAP item: the resident-vs-streaming normalization fallback is a
    register_variant axis the tuner can evaluate (and correctly rejects —
    streaming re-reads each row, so resident wins on traffic)."""
    from repro.bench import suite
    task = {t.name: t for t in suite()}["softmax"]
    assert {"default", "streaming"} <= set(variants_for("softmax"))
    assert {"default", "streaming"} <= set(variants_for("rmsnorm"))
    tr = tune(task, budget=4, cache=str(tmp_path))
    streaming = [t for t in tr.trials
                 if t.candidate.variant == "streaming"]
    assert streaming and streaming[0].ok, "streaming variant did not build"
    assert tr.best.candidate.variant == "default"
    assert streaming[0].ratio < tr.best.ratio


# ---------------------------------------------------------------------------
# VMEM refusal -> unfused fallback
# ---------------------------------------------------------------------------

_WIDE = ChainSpec(
    name="wide_add_gelu",
    inputs=(("input", 2), ("other", 2)),
    outputs=("output",),
    stages=(ChainStage("add", ("input", "other"), "h"),
            ChainStage("gelu", ("h",), "output")))
# fused footprint at block_rows=1 is 4 row tiles (input, other, sum, gelu
# temp); the sequential baseline reuses stage-0 tiles and needs only 3 —
# a column count between the two refusal points exercises the fallback
_WIDE_SHAPES = {"input": (1, 589824), "other": (1, 589824),
                "output": (1, 589824)}


def test_fused_vmem_refusal_falls_back_to_sequential():
    with pytest.raises(NotImplementedError):
        build_chain(_WIDE, _WIDE_SHAPES, mode="fused")
    prog = build_fused(_WIDE, _WIDE_SHAPES, fallback=True)
    assert prog.meta["fusion"]["mode"] == "sequential"
    # and the chain still covers every element: interpreter smoke run
    rng = np.random.RandomState(0)
    small = {"input": (2, 256), "other": (2, 256), "output": (2, 256)}
    sprog = build_chain(_WIDE, small, mode="sequential")
    x = rng.randn(2, 256).astype(np.float32)
    o = rng.randn(2, 256).astype(np.float32)
    out = interpret(sprog, {"input": x, "other": o},
                    {"output": (2, 256)})["output"]
    assert np.isfinite(out).all()


def test_resolve_and_build_shared_fallback_policy():
    """The extracted resolve-and-build helper applies the registered
    fallback for the default variant only."""
    from repro.bench import suite
    task = {t.name: t for t in suite()}["softmax"]
    import dataclasses
    long_rows = dataclasses.replace(
        task, shapes={"input": (8, 4 * 1024 * 1024),
                      "output": (8, 4 * 1024 * 1024)})
    art, resolved = resolve_and_build(
        long_rows, PLANNER_REGISTRY["softmax"], "default", None,
        long_rows.shapes, check_shapes=None, verify_against_interp=False)
    assert resolved == "softmax_streaming"
    with pytest.raises(NotImplementedError):
        resolve_and_build(long_rows, PLANNER_REGISTRY["softmax"],
                          "not-default", None, long_rows.shapes,
                          check_shapes=None, verify_against_interp=False)


# ---------------------------------------------------------------------------
# Cache fingerprints
# ---------------------------------------------------------------------------

def test_fused_artifacts_get_distinct_cache_keys(tasks, tmp_path):
    cache = ArtifactCache(str(tmp_path))
    task = tasks["bias_gelu"]
    k_seq = cache.key_for(task, Knobs(), variant="default")
    k_fused = cache.key_for(task, Knobs(), variant="fused")
    assert k_seq != k_fused
    # a plain task with the same tensors but no chain attrs keys differently
    import dataclasses
    plain = dataclasses.replace(task, attrs={})
    assert cache.key_for(plain, Knobs()) != cache.key_for(task, Knobs())


def test_fused_artifact_roundtrips_through_cache(tasks, tmp_path):
    """generate(tune=True) caches the fused winner; the second call serves
    the fused program from the cache with no search and no lowering."""
    from repro.core.lowering.pipeline import PIPELINE_COUNTERS
    cache = ArtifactCache(str(tmp_path))
    task = tasks["bias_gelu"]
    r1 = generate(task, tune=True, tune_budget=6, cache=cache)
    assert r1.pass_ok and r1.tune is not None
    assert r1.tune.best.candidate.variant == "fused"
    assert r1.artifact.program.name.endswith("_fused")
    before = dict(PIPELINE_COUNTERS)
    r2 = generate(task, tune=True, tune_budget=6, cache=cache)
    assert r2.cached and r2.tune is None
    assert r2.artifact.program.name.endswith("_fused")
    assert dict(PIPELINE_COUNTERS) == before


# ---------------------------------------------------------------------------
# Property: fused == sequential composition under the DSL interpreter
# ---------------------------------------------------------------------------

def _random_spec(ops, binary_first):
    stages = []
    prev = "input"
    extra_inputs = []
    for i, op in enumerate(ops):
        out = "output" if i == len(ops) - 1 else f"h{i}"
        if i == 0 and binary_first:
            extra_inputs.append("other")
            stages.append(ChainStage(op if op in ("add", "mul") else "add",
                                     (prev, "other"), out))
        else:
            stages.append(ChainStage(op, (prev,), out))
        prev = out
    return ChainSpec(
        name="prop_chain",
        inputs=tuple([("input", 2)] + [(n, 2) for n in extra_inputs]),
        outputs=("output",),
        stages=tuple(stages))


_ELEMWISE = ["gelu", "silu", "relu", "tanh", "sigmoid", "abs", "square"]


def _property_cases(n=15, seed=20260727):
    """Deterministic random chain generator (hypothesis-style coverage
    without the dependency — the container may not ship hypothesis)."""
    rng = np.random.RandomState(seed)
    for _ in range(n):
        rows = int(rng.randint(1, 13))
        cols = int(rng.randint(4, 401))
        ops = [str(rng.choice(_ELEMWISE))
               for _ in range(int(rng.randint(2, 5)))]
        yield rows, cols, ops, bool(rng.randint(2)), int(rng.randint(2**31))


@pytest.mark.parametrize("rows,cols,ops,binary_first,seed",
                         list(_property_cases()))
def test_fuse_equals_sequential_composition(rows, cols, ops, binary_first,
                                            seed):
    """fuse_programs output == the sequential composition under the DSL
    numpy interpreter, on randomly generated compatible chains (both run
    on the lane-padded GM the programs address)."""
    spec = _random_spec(ops, binary_first)
    cols_p = -(-cols // 128) * 128
    shapes = {t: ((rows, cols) if r == 2 else (cols,))
              for t, r in spec.inputs}
    shapes["output"] = (rows, cols)
    fused = build_chain(spec, shapes, mode="fused")
    seq = build_chain(spec, shapes, mode="sequential")
    assert fused.meta["fusion"]["mode"] == "fused"
    assert seq.meta["fusion"]["mode"] == "sequential"
    # fusion really deleted the link round trip
    n_loads_f = sum(1 for s, _ in A.walk_stmts(fused.kernel.body)
                    if isinstance(s, A.Load))
    n_loads_s = sum(1 for s, _ in A.walk_stmts(seq.kernel.body)
                    if isinstance(s, A.Load))
    assert n_loads_f < n_loads_s

    rng = np.random.RandomState(seed)
    inputs = {t: np.pad(rng.randn(*shapes[t]).astype(np.float32),
                        [(0, 0)] * (len(shapes[t]) - 1)
                        + [(0, cols_p - cols)])
              for t, _ in spec.inputs}
    out_shapes = {"output": (rows, cols_p)}
    got_f = interpret(fused, inputs, out_shapes)["output"]
    got_s = interpret(seq, inputs, out_shapes)["output"]
    np.testing.assert_allclose(got_f[:, :cols], got_s[:, :cols],
                               rtol=0, atol=0)
