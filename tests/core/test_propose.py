"""Dataflow-driven chain proposal (DESIGN.md §10): golden re-derivation of
the PR-2 hand-declared chains, graph segmentation, escape analysis, and
neutral-pad propagation."""
import pytest

from repro.core.fusion import (CHAINS, ChainSpec, ChainStage, GRAPHS,
                               OpGraph, OpNode, ProposeError, propose_chains)


# ---------------------------------------------------------------------------
# Golden: the proposer re-derives the four chains PR 2 declared by hand
# (their CHAINS entries are deleted; these golden specs pin the proposer)
# ---------------------------------------------------------------------------

GOLDEN = {
    "bias_gelu": ChainSpec(
        name="bias_gelu",
        inputs=(("input", 2), ("bias", 1)),
        outputs=("output",),
        stages=(ChainStage("add", ("input", "bias"), "h"),
                ChainStage("gelu", ("h",), "output"))),
    "mul_softmax": ChainSpec(
        name="mul_softmax",
        inputs=(("input", 2), ("scale", 1)),
        outputs=("output",),
        stages=(ChainStage("mul", ("input", "scale"), "h"),
                ChainStage("softmax", ("h",), "output")),
        # computed pad of h = -3e38 * 1.0 — softmax's neutral element
        pad_values=(("input", -3.0e38), ("scale", 1.0))),
    "rmsnorm_swiglu": ChainSpec(
        name="rmsnorm_swiglu",
        inputs=(("input", 2), ("weight", 1), ("gate", 2)),
        outputs=("output",),
        stages=(ChainStage("rmsnorm", ("input", "weight"), "h"),
                ChainStage("swiglu", ("h", "gate"), "output"))),
    # the updated residual stream escapes (graph output), so the proposer
    # must keep its Store and route the sequential round trip through it
    "add_rmsnorm": ChainSpec(
        name="add_rmsnorm",
        inputs=(("input", 2), ("residual", 2), ("weight", 1)),
        outputs=("output", "new_residual"),
        stages=(ChainStage("add", ("input", "residual"), "new_residual"),
                ChainStage("rmsnorm", ("new_residual", "weight"), "output")),
        keep=(("new_residual", "new_residual"),),
        route=(("new_residual", "new_residual"),)),
}


def test_proposer_rederives_hand_declared_chains():
    for name, want in GOLDEN.items():
        assert name in CHAINS, f"proposer lost chain '{name}'"
        assert CHAINS[name] == want, f"proposed '{name}' != golden spec"


def test_new_chains_are_proposed_and_registered():
    """The streaming-pattern and DAG-shaped chains exist, are planner
    defaults, carry the streaming fallback entry, and ride the tuner's
    variant axis."""
    from repro.core.planner import PLANNER_REGISTRY
    from repro.core.tuning import variants_for
    assert "attn_scores" in CHAINS and "swiglu_proj" in CHAINS
    for name in CHAINS:
        assert name in PLANNER_REGISTRY
        assert f"{name}_streaming" in PLANNER_REGISTRY
        assert "fused" in variants_for(name)
    # attn_scores derived a 2-level pad propagation: input pads with
    # softmax's neutral element THROUGH mul and add
    assert dict(CHAINS["attn_scores"].pad_values) == {"input": -3.0e38,
                                                      "scale": 1.0}
    # swiglu_proj is DAG-shaped: two stages read the same chain input
    readers = [st for st in CHAINS["swiglu_proj"].stages
               if "input" in st.inputs]
    assert len(readers) == 2


# ---------------------------------------------------------------------------
# Segmentation: non-fusable nodes split the graph
# ---------------------------------------------------------------------------

def test_non_fusable_node_splits_graph_into_two_chains():
    # matmul is a stage now (DESIGN.md §13), so the canonical splitter is
    # an extractor-declared barrier (rank-changing contraction the matmul
    # template refuses — see test_fusion's negative-path coverage)
    g = OpGraph(
        name="block",
        inputs=(("x", 2), ("b", 1), ("w", 1)),
        outputs=("y",),
        nodes=(OpNode("add", ("x", "b"), "h1"),
               OpNode("gelu", ("h1",), "h2"),
               OpNode("barrier.dot_general", ("h2", "w"), "h3",
                      out_rank=2),            # not fusable
               OpNode("rmsnorm", ("h3", "w"), "h4"),
               OpNode("silu", ("h4",), "y")))
    specs = propose_chains(g)
    assert len(specs) == 2
    first, second = specs
    # chain 1: add+gelu; its output h2 escapes (consumed by the barrier)
    assert [st.op for st in first.stages] == ["add", "gelu"]
    assert first.outputs == ("h2",)
    # chain 2: rmsnorm+silu; the barrier's output re-enters as an input
    assert [st.op for st in second.stages] == ["rmsnorm", "silu"]
    assert second.inputs[0] == ("h3", 2)
    assert second.outputs == ("y",)
    assert first.name != second.name


def test_escaping_mid_link_is_kept():
    """A link consumed downstream AND observed by the graph keeps its
    Store (escape analysis), like add_rmsnorm's residual stream."""
    g = OpGraph(
        name="expose",
        inputs=(("x", 2),),
        outputs=("y", "mid"),
        nodes=(OpNode("gelu", ("x",), "mid"),
               OpNode("silu", ("mid",), "y")))
    (spec,) = propose_chains(g)
    assert spec.keep == (("mid", "mid"),)
    assert set(spec.outputs) == {"y", "mid"}


def test_single_node_components_are_not_proposed():
    g = OpGraph(name="lone", inputs=(("x", 2),), outputs=("y",),
                nodes=(OpNode("gelu", ("x",), "y"),))
    assert propose_chains(g) == []


# ---------------------------------------------------------------------------
# Pad propagation failures refuse instead of mis-fusing
# ---------------------------------------------------------------------------

def test_pad_propagation_refuses_non_neutralizable_producer():
    # sigmoid cannot map any pad to softmax's -3e38 neutral element
    g = OpGraph(
        name="bad",
        inputs=(("x", 2),),
        outputs=("y",),
        nodes=(OpNode("sigmoid", ("x",), "h"),
               OpNode("softmax", ("h",), "y")))
    with pytest.raises(ProposeError):
        propose_chains(g)


def test_pad_requirement_conflict_is_detected():
    # s is the mul's second operand (needs pad 1.0) AND the add's second
    # operand (needs pad 0.0): one tensor cannot carry both
    with pytest.raises(ProposeError):
        propose_chains(OpGraph(
            name="conflict",
            inputs=(("x", 2), ("s", 1)),
            outputs=("y",),
            nodes=(OpNode("mul", ("x", "s"), "h"),
                   OpNode("add", ("h", "s"), "h2"),
                   OpNode("softmax", ("h2",), "y"))))


def test_bad_graphs_error():
    with pytest.raises(ProposeError):       # undeclared tensor
        propose_chains(OpGraph(
            name="g", inputs=(("x", 2),), outputs=("y",),
            nodes=(OpNode("add", ("x", "ghost"), "y"),)))
    with pytest.raises(ProposeError):       # produced twice
        propose_chains(OpGraph(
            name="g", inputs=(("x", 2),), outputs=("y",),
            nodes=(OpNode("gelu", ("x",), "y"),
                   OpNode("silu", ("x",), "y"))))
    with pytest.raises(ProposeError):       # cyclic
        propose_chains(OpGraph(
            name="g", inputs=(("x", 2),), outputs=("y",),
            nodes=(OpNode("add", ("x", "b"), "y"),
                   OpNode("gelu", ("y",), "b"))))


def test_declared_graphs_all_propose():
    """Every declared golden-fixture graph yields at least one chain, and
    every fixture chain is registered.  (CHAINS may hold MORE than the
    fixtures: jaxpr extraction contributes chains of its own, e.g.
    mask_softmax — see test_extract.py.)"""
    names = set()
    for g in GRAPHS:
        specs = propose_chains(g)
        assert specs, f"graph '{g.name}' proposed nothing"
        names.update(s.name for s in specs)
    assert names <= set(CHAINS)


def test_every_chain_is_extraction_derived():
    """The jaxpr extractor is the source of truth (DESIGN.md §11): every
    registered chain — declared fixture or not — must be re-derivable from
    a traced model workload.  A declared graph without a model workload
    backing it may not register."""
    from repro.core.fusion import CHAIN_SOURCES
    assert set(CHAIN_SOURCES) == set(CHAINS)
    for name, sources in CHAIN_SOURCES.items():
        assert "extracted" in sources, (
            f"chain '{name}' is not derived from any traced model "
            f"workload (sources={sources})")


# ---------------------------------------------------------------------------
# Per-stat pad absorption (DESIGN.md §12): a stat producer ABSORBS the
# downstream neutral-pad requirement as a link pad (blend), instead of
# refusing the chain
# ---------------------------------------------------------------------------

def test_stat_producer_absorbs_downstream_pad_as_link_pad():
    g = OpGraph(
        name="double_softmax",
        inputs=(("x", 2),),
        outputs=("y",),
        nodes=(OpNode("softmax", ("x",), "h"),
               OpNode("softmax", ("h",), "y")))
    (spec,) = propose_chains(g)
    assert dict(spec.pad_values) == {"x": -3.0e38, "h": -3.0e38}
    assert spec.link_pad("h") == -3.0e38
    assert spec.link_pad("y") is None


def test_map_producer_still_refuses_unpropagatable_pad():
    """Absorption is a STAT capability (the stat templates blend their
    output pass); a map op like sigmoid still has no backward rule for a
    -3e38 requirement and must refuse."""
    g = OpGraph(
        name="bad",
        inputs=(("x", 2),),
        outputs=("y",),
        nodes=(OpNode("sigmoid", ("x",), "h"),
               OpNode("softmax", ("h",), "y")))
    with pytest.raises(ProposeError):
        propose_chains(g)


def test_rmsnorm_input_now_requires_zero_pad():
    """rmsnorm/layernorm seed a 0.0 requirement on their row input (their
    sum-of-squares/mean must not see garbage): a producer that cannot
    deliver 0 at the pads refuses instead of silently mis-fusing."""
    g = OpGraph(
        name="sig_rms",
        inputs=(("x", 2), ("w", 1)),
        outputs=("y",),
        nodes=(OpNode("sigmoid", ("x",), "h"),      # sigmoid(0) = 0.5 != 0
               OpNode("rmsnorm", ("h", "w"), "y")))
    with pytest.raises(ProposeError):
        propose_chains(g)


def test_node_attrs_merge_into_component_attrs():
    g = OpGraph(
        name="eps_chain",
        inputs=(("x", 2), ("w", 1)),
        outputs=("y",),
        nodes=(OpNode("rmsnorm", ("x", "w"), "h",
                      attrs=(("eps", 1e-4),)),
               OpNode("silu", ("h",), "y")))
    (spec,) = propose_chains(g)
    assert dict(spec.attrs) == {"eps": 1e-4}


def test_conflicting_node_attrs_qualify_per_stage():
    # conflicting per-node attr values no longer refuse: each is kept
    # under a ``key@<node output>`` qualified name so every stage can
    # recover its own value
    g = OpGraph(
        name="eps_conflict",
        inputs=(("x", 2), ("w", 1), ("w2", 1)),
        outputs=("y",),
        nodes=(OpNode("rmsnorm", ("x", "w"), "h",
                      attrs=(("eps", 1e-4),)),
               OpNode("rmsnorm", ("h", "w2"), "y",
                      attrs=(("eps", 2e-4),))))
    chains = propose_chains(g)
    assert len(chains) == 1
    attrs = dict(chains[0].attrs)
    assert attrs["eps@h"] == pytest.approx(1e-4)
    assert attrs["eps@y"] == pytest.approx(2e-4)
