"""Paper Table 1 reproduction as a test: all 52 kernels + the 2 mHC kernels
must generate, compile and pass numerically (our deterministic planner
removes the paper's LLM variance; paper totals were 98.1 / 90.4)."""
import pytest

from repro.bench import suite
from repro.bench.mhc import mhc_tasks
from repro.core.planner import generate

_TASKS = {t.name: t for t in suite()}
_TASKS.update({t.name: t for t in mhc_tasks()})


@pytest.mark.parametrize("name", sorted(_TASKS))
def test_kernel_generates_and_passes(name):
    r = generate(_TASKS[name])
    assert r.comp_ok, f"Comp@1 failed: {r.error}"
    assert r.pass_ok, f"Pass@1 failed: {r.error} (err={r.max_abs_err:.3g})"


def test_category_counts_match_paper_table1():
    from collections import Counter
    counts = Counter(t.category for t in suite())
    assert counts == {"activation": 15, "loss": 7, "math": 6,
                      "normalization": 8, "optimizer": 5, "reduce": 5,
                      "pooling": 6}
