"""§Perf kernel-level hillclimb artifacts stay correct and faster-by-model."""
import numpy as np
import pytest

from repro.bench import suite
from repro.bench.model import fast_ratio
from repro.core.examples.pooling import build_pool2d_rowreuse
from repro.core.lowering.pipeline import transcompile, Knobs
from repro.core.planner import default_inputs, generate


@pytest.mark.parametrize("mode,name", [("avg", "avg_pool2d"),
                                       ("max", "max_pool2d")])
def test_pool2d_rowreuse_correct_and_faster(mode, name):
    task = {t.name: t for t in suite()}[name]
    prog = build_pool2d_rowreuse(task, task.check_shapes, Knobs(), mode)
    art = transcompile(prog)
    inputs = default_inputs(task, task.check_shapes)
    got = np.asarray(art.entry(inputs["input"], interpret=True))
    want = task.ref(inputs["input"])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    base = generate(task, verify=False)
    prog_big = build_pool2d_rowreuse(task, task.shapes, Knobs(), mode)
    assert fast_ratio(task, prog_big) > fast_ratio(
        task, base.artifact.program) * 1.2
