"""Resilience subsystem (DESIGN.md §14): the deterministic fault-injection
harness, the degradation ladder, cache self-healing under injected faults,
the quarantine table, tuned-pointer locking, and the serving engine's
survive-anything guarantees.

CI runs this file with ``REPRO_FAULT_INJECTION=1``, which additionally
arms the final audit test: every named hook point must have been VISITED
by the suite, proving the hooks stay wired as the instrumented call sites
evolve."""
import os
import time

import numpy as np
import pytest

from repro.bench import suite
from repro.core.planner import default_inputs, generate
from repro.core.resilience import (FAULT_AUDIT, HOOK_POINTS, FaultClock,
                                   FaultInjected, FaultPlan, FaultSpec,
                                   GuardedResolver, PersistentQuarantine,
                                   Quarantine, corrupt_cache_entry,
                                   drain_events, fault_point, inject,
                                   poison_nan_result)
from repro.core.tuning import ArtifactCache


@pytest.fixture(scope="module")
def tasks():
    return {t.name: t for t in suite()}


@pytest.fixture(autouse=True)
def _fresh_event_log():
    drain_events()
    yield
    drain_events()


def _arrays(task):
    inputs = default_inputs(task, task.check_shapes)
    return [inputs[tp.name] for tp in task.input_specs]


# ---------------------------------------------------------------------------
# Fault harness mechanics: deterministic, counter-driven, scoped
# ---------------------------------------------------------------------------

def test_fault_spec_counters_are_deterministic():
    spec = FaultSpec("cache.get", match="relu", after=1, times=2)
    fire = [spec.arm_for(tok) for tok in
            ("softmax", "relu", "relu", "relu", "relu")]
    # non-matching token never counted; then skip 1, fire 2, exhausted
    assert fire == [False, False, True, True, False]
    assert spec.seen == 4 and spec.fired == 2


def test_fault_spec_rejects_unknown_site():
    with pytest.raises(ValueError, match="unknown hook point"):
        FaultSpec("cache.gett")
    with pytest.raises(ValueError, match="needs fn"):
        FaultSpec("cache.get", kind="call")


def test_fault_point_is_noop_without_plan():
    before = FAULT_AUDIT.get("cache.get", 0)
    payload = {"x": 1}
    assert fault_point("cache.get", payload, token="k") is payload
    assert FAULT_AUDIT["cache.get"] == before + 1   # visits always counted


def test_inject_is_dynamically_scoped():
    plan = FaultPlan([FaultSpec("cache.get", times=None)])
    with inject(plan):
        with pytest.raises(FaultInjected):
            fault_point("cache.get", token="k")
    fault_point("cache.get", token="k")             # no plan: no raise
    assert plan.fired("cache.get") == 1


# ---------------------------------------------------------------------------
# The degradation ladder
# ---------------------------------------------------------------------------

def test_clean_resolve_lands_top_rung_with_zero_events(tasks, tmp_path):
    cache = ArtifactCache(str(tmp_path))
    res = GuardedResolver(cache=cache, tune=False,
                          quarantine=Quarantine()).resolve(tasks["relu"])
    assert res.rung == "cached_tuned"
    assert res.events == () and res.verdict == "ok" and not res.degraded
    x = _arrays(tasks["relu"])[0]
    np.testing.assert_allclose(np.asarray(res(x)), np.maximum(x, 0),
                               rtol=1e-6, atol=1e-6)
    # second resolve is a cache hit on the same rung
    res2 = GuardedResolver(cache=cache, tune=False,
                           quarantine=Quarantine()).resolve(tasks["relu"])
    assert res2.rung == "cached_tuned" and res2.result.cached


def test_ladder_descends_to_eager_when_every_generate_fails(tasks, tmp_path):
    task = tasks["relu"]                 # relu has no streaming fallback
    plan = FaultPlan([FaultSpec("planner.generate", times=None)])
    with inject(plan):
        res = GuardedResolver(cache=ArtifactCache(str(tmp_path)),
                              tune=False,
                              quarantine=Quarantine()).resolve(task)
    assert res.rung == "eager" and res.verdict == "degraded"
    assert [e.rung for e in res.events] == ["cached_tuned", "regenerate",
                                            "sequential"]
    assert all(e.cause == "error" for e in res.events)
    assert all(e.fingerprint == res.fingerprint for e in res.events)
    x = _arrays(task)[0]                 # the eager floor still serves
    np.testing.assert_allclose(np.asarray(res(x)), np.maximum(x, 0))


def test_ladder_lands_streaming_rung(tasks):
    """softmax HAS a registered ``softmax_streaming`` fallback: failing the
    first two generation rungs must land there, not at sequential."""
    task = tasks["softmax"]
    plan = FaultPlan([FaultSpec("planner.generate", times=1)])
    with inject(plan):
        res = GuardedResolver(cache=None, tune=False,
                              quarantine=Quarantine()).resolve(task)
    # cache=None: ladder is regenerate -> streaming -> sequential -> eager
    assert res.rung == "streaming"
    assert [e.rung for e in res.events] == ["regenerate"]
    assert res.result.comp_ok and res.result.pass_ok


def test_fused_chain_build_fault_descends_and_eager_matches_ref():
    from repro.bench.tasks import fused_suite
    task = [t for t in fused_suite() if t.name == "bias_gelu"][0]
    plan = FaultPlan([FaultSpec("fusion.build_chain", times=None)])
    with inject(plan):
        res = GuardedResolver(cache=None, tune=False,
                              quarantine=Quarantine()).resolve(task)
    assert res.rung == "eager"
    assert plan.fired("fusion.build_chain") >= 1
    arrays = _arrays(task)
    np.testing.assert_allclose(np.asarray(res(*arrays)),
                               np.asarray(task.ref(*arrays)),
                               rtol=1e-5, atol=1e-6)


def test_nan_sentinel_demotes_poisoned_kernel(tasks, tmp_path):
    """A kernel whose verification verdict is green but whose runtime
    output is NaN (the mis-fused-chain failure mode) is caught by the
    first-call sentinel and demoted to the sequential rung."""
    task = tasks["relu"]
    plan = FaultPlan([FaultSpec("planner.generate:result", kind="call",
                                fn=poison_nan_result, times=2)])
    with inject(plan):
        res = GuardedResolver(cache=ArtifactCache(str(tmp_path)),
                              tune=False, verify=True, sentinel=True,
                              quarantine=Quarantine()).resolve(task)
    assert res.rung == "sequential"
    assert [e.cause for e in res.events] == ["nan-sentinel", "nan-sentinel"]
    x = _arrays(task)[0]
    assert np.all(np.isfinite(np.asarray(res(x))))


def test_quarantine_skips_known_bad_rungs(tasks):
    task = tasks["relu"]
    q = Quarantine(threshold=2)
    plan = FaultPlan([FaultSpec("planner.generate", times=None)])
    with inject(plan):
        for _ in range(2):
            GuardedResolver(cache=None, tune=False,
                            quarantine=q).resolve(task)
    fp = GuardedResolver._fingerprint(task)
    assert q.blocked(fp, "regenerate") and q.blocked(fp, "sequential")
    # injection OFF now — but the quarantined rungs are skipped without
    # being re-attempted, pushing the request to the eager floor
    before = FAULT_AUDIT.get("planner.generate", 0)
    res = GuardedResolver(cache=None, tune=False, quarantine=q).resolve(task)
    assert res.rung == "eager" and res.verdict == "quarantined"
    assert all(e.cause == "quarantined" for e in res.events)
    assert FAULT_AUDIT.get("planner.generate", 0) == before  # truly skipped
    q.clear()
    assert not q.blocked(fp, "regenerate")


def test_rung_timeout_stops_retries(tasks):
    task = tasks["relu"]
    plan = FaultPlan([FaultSpec("planner.generate", times=None)])
    with inject(plan):
        res = GuardedResolver(cache=None, tune=False, attempts=50,
                              rung_timeout_s=0.0,
                              quarantine=Quarantine()).resolve(task)
    assert res.rung == "eager"
    # one attempt per rung, then the timeout fires instead of 49 retries
    assert plan.fired("planner.generate") == 2      # regenerate + sequential
    assert {e.cause for e in res.events} == {"timeout"}


# ---------------------------------------------------------------------------
# Self-healing cache under injected faults
# ---------------------------------------------------------------------------

def test_corrupt_cache_entry_heals_inside_top_rung(tasks, tmp_path):
    """Corruption is NOT a degradation: the cache evicts the damaged entry
    and the same rung regenerates — the resolver never descends."""
    cache = ArtifactCache(str(tmp_path))
    task = tasks["relu"]
    generate(task, verify=False, cache=cache)            # seed
    plan = FaultPlan([FaultSpec("cache.get", kind="call",
                                fn=corrupt_cache_entry("garble_source"))])
    with inject(plan):
        res = GuardedResolver(cache=cache, tune=False, verify=False,
                              quarantine=Quarantine()).resolve(task)
    assert res.rung == "cached_tuned" and res.events == ()
    assert cache.evictions == 1
    assert not res.result.cached                          # regenerated
    # the healed entry is clean: next resolve is a plain hit
    res2 = GuardedResolver(cache=cache, tune=False, verify=False,
                           quarantine=Quarantine()).resolve(task)
    assert res2.result.cached and cache.evictions == 1


def test_cache_get_filesystem_error_degrades_not_raises(tasks, tmp_path):
    cache = ArtifactCache(str(tmp_path))
    task = tasks["relu"]
    generate(task, verify=False, cache=cache)
    plan = FaultPlan([FaultSpec("cache.get", times=None)])
    with inject(plan):
        res = GuardedResolver(cache=cache, tune=False, verify=False,
                              quarantine=Quarantine()).resolve(task)
    # the injected store error fails the cached rung; regenerate serves
    assert res.rung == "regenerate"
    assert [e.rung for e in res.events] == ["cached_tuned"]


def test_cache_put_fault_is_swallowed(tasks, tmp_path):
    cache = ArtifactCache(str(tmp_path))
    task = tasks["relu"]
    plan = FaultPlan([FaultSpec("cache.put")])
    with inject(plan):
        r = generate(task, verify=False, cache=cache)
    assert r.comp_ok                       # generation itself unaffected
    assert cache.put_errors == 1 and cache.num_entries() == 0
    assert generate(task, verify=False, cache=cache).comp_ok


def test_cache_materialize_fault_is_a_miss(tasks, tmp_path):
    cache = ArtifactCache(str(tmp_path))
    task = tasks["relu"]
    generate(task, verify=False, cache=cache)
    plan = FaultPlan([FaultSpec("cache.materialize")])
    with inject(plan):
        r = generate(task, verify=False, cache=cache)
    assert r.comp_ok and not r.cached      # hit turned into a rebuild
    assert generate(task, verify=False, cache=cache).cached


def test_put_tuned_backs_off_live_lock_and_cleans_stale(tasks, tmp_path):
    from repro.core.tuning import tune
    cache = ArtifactCache(str(tmp_path))
    task = tasks["relu"]
    tr = tune(task, budget=1, cache=cache)
    cand = tr.best.candidate
    lock = cache._tuned_path(task).with_suffix(".lock")

    lock.touch()                           # FRESH lock: live writer owns it
    assert cache.put_tuned(task, cand, 9.9) is False
    rec = cache.get_tuned(task)
    assert rec is None or rec["ratio"] != 9.9

    old = time.time() - 3600               # STALE lock: writer died
    os.utime(lock, (old, old))
    assert cache.put_tuned(task, cand, 9.9) is True
    assert not lock.exists()
    assert cache.get_tuned(task)["ratio"] == 9.9


# ---------------------------------------------------------------------------
# Persistent quarantine: the failure table survives restarts (DESIGN.md §15)
# ---------------------------------------------------------------------------

def test_persistent_quarantine_round_trips_across_instances(tmp_path):
    p = tmp_path / "q.json"
    q = PersistentQuarantine(p, threshold=2)
    q.note_failure("fp1", "regenerate")
    q.note_failure("fp1", "regenerate")
    q.note_failure("fp2", "sequential")
    assert q.blocked("fp1", "regenerate")
    # "restart": a fresh instance loads the same table
    q2 = PersistentQuarantine(p, threshold=2)
    assert q2.blocked("fp1", "regenerate")
    assert not q2.blocked("fp2", "sequential")
    assert q2.entries() == {("fp1", "regenerate"): 2,
                            ("fp2", "sequential"): 1}
    q2.clear()
    assert PersistentQuarantine(p, threshold=2).entries() == {}


def test_persistent_quarantine_expires_stale_entries(tmp_path):
    clk = FaultClock(t0=1000.0)
    p = tmp_path / "q.json"
    mk = lambda: PersistentQuarantine(p, threshold=1, max_age_s=100.0,  # noqa
                                      clock=clk)
    mk().note_failure("fp", "sequential")
    clk.advance(50.0)
    assert mk().blocked("fp", "sequential")      # still fresh
    clk.advance(100.0)                           # now 150s old: expired
    assert not mk().blocked("fp", "sequential")
    assert mk().entries() == {}


def test_persistent_quarantine_corrupt_table_loads_empty(tmp_path):
    p = tmp_path / "q.json"
    p.write_text("{this is not json")
    q = PersistentQuarantine(p, threshold=1)
    assert q.entries() == {}
    q.note_failure("fp", "regenerate")           # and heals by overwriting
    assert PersistentQuarantine(p).entries() == {("fp", "regenerate"): 1}


def test_persistent_quarantine_from_cache_placement(tmp_path):
    cache = ArtifactCache(str(tmp_path))
    q = PersistentQuarantine.from_cache(cache, threshold=1)
    q.note_failure("fp", "regenerate")
    assert (tmp_path / "quarantine.json").exists()
    with pytest.raises(ValueError, match="no cache to persist"):
        PersistentQuarantine.from_cache(None)


def test_persistent_quarantine_survives_resolver_restart(tasks, tmp_path):
    """The ladder integration: failures noted through a GuardedResolver
    persist, and a RESTARTED process (fresh table instance, injection off)
    skips the quarantined rungs without re-attempting them."""
    task = tasks["relu"]
    p = tmp_path / "q.json"
    plan = FaultPlan([FaultSpec("planner.generate", times=None)])
    with inject(plan):
        for _ in range(3):
            GuardedResolver(cache=None, tune=False,
                            quarantine=PersistentQuarantine(p)
                            ).resolve(task)
    res = GuardedResolver(cache=None, tune=False,
                          quarantine=PersistentQuarantine(p)).resolve(task)
    assert res.rung == "eager" and res.verdict == "quarantined"


# ---------------------------------------------------------------------------
# FaultClock: deterministic wall time driven by hook visits
# ---------------------------------------------------------------------------

def test_fault_clock_ticker_advances_per_hook_visit():
    clk = FaultClock(t0=10.0)
    plan = FaultPlan([FaultSpec("serve.decode", kind="call",
                                fn=clk.ticker(0.5), times=None)])
    with inject(plan):
        payload = {"x": 1}
        assert fault_point("serve.decode", payload, token="step=0") is payload
        fault_point("serve.decode", token="step=1")
    fault_point("serve.decode", token="step=2")  # no plan: clock frozen
    assert clk() == 11.0


# ---------------------------------------------------------------------------
# Serving engine survival (retry / requeue / poison isolation / deadline)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serve_env():
    import jax
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("internlm2-1.8b", smoke=True)
    return cfg, T.init_params(jax.random.PRNGKey(0), cfg)


def _engine(env, slots=2):
    from repro.serving import ServeEngine
    cfg, params = env
    return ServeEngine(params, cfg, batch_slots=slots, max_len=64)


def _requests(env, n, max_new=4):
    from repro.serving import Request
    cfg, _ = env
    rng = np.random.RandomState(0)
    return [Request(uid=i, prompt=rng.randint(0, cfg.vocab, 8)
                    .astype(np.int32), max_new_tokens=max_new)
            for i in range(n)]


def test_serve_transient_admit_fault_requeues_and_completes(serve_env):
    eng = _engine(serve_env)
    reqs = _requests(serve_env, 3)
    plan = FaultPlan([FaultSpec("serve.admit", times=1)])   # one prefill crash
    with inject(plan):
        eng.run(reqs)
    rep = eng.last_report
    assert rep.ok and not rep.failed
    assert rep.requeues == 1 and rep.admit_retries == 1
    assert sorted(rep.completed) == [0, 1, 2]
    assert all(r.done and len(r.generated) == 4 and not r.error
               for r in reqs)


def test_serve_poison_request_is_isolated(serve_env):
    eng = _engine(serve_env)
    reqs = _requests(serve_env, 3)
    plan = FaultPlan([FaultSpec("serve.admit", match="uid=1", times=None)])
    with inject(plan):
        out = eng.run(reqs)
    assert out is reqs                      # back-compat return value
    rep = eng.last_report
    assert [f["uid"] for f in rep.failed] == [1]
    assert rep.failed[0]["phase"] == "admit"
    assert "FaultInjected" in reqs[1].error and reqs[1].done
    assert sorted(rep.completed) == [0, 2]
    assert all(len(reqs[i].generated) == 4 for i in (0, 2))


def test_serve_decode_crash_evicts_newest_and_continues(serve_env):
    eng = _engine(serve_env)
    reqs = _requests(serve_env, 3)
    # step 1 fails twice (attempt + retry) -> poison isolation evicts the
    # newest admission; the 3rd firing is absorbed by the next retry
    plan = FaultPlan([FaultSpec("serve.decode", times=3)])
    with inject(plan):
        eng.run(reqs, decode_retries=1)
    rep = eng.last_report
    assert [f["uid"] for f in rep.failed] == [1]    # newest of slots {0,1}
    assert rep.failed[0]["phase"] == "decode"
    assert rep.decode_retries == 2
    assert sorted(rep.completed) == [0, 2]
    assert all(len(reqs[i].generated) == 4 for i in (0, 2))


def test_serve_deadline_bounds_the_run(serve_env):
    eng = _engine(serve_env)
    reqs = _requests(serve_env, 2, max_new=6)
    eng.run(reqs, max_steps=2)
    rep = eng.last_report
    assert rep.deadline_hit and not rep.ok
    assert rep.decode_steps == 2
    assert {f["phase"] for f in rep.failed} == {"deadline"}
    assert all(r.done for r in reqs)


def test_serve_fastpath_fault_never_breaks_the_decode_loop(serve_env):
    """An armed raise at serve.decode_fastpath (every bucket resolution
    fails) is CONTAINED: the run completes cleanly, every token is
    generated, and the failures are only visible as fastpath_errors."""
    eng = _engine(serve_env)
    assert eng.fastpath is not None              # the default-on fast path
    reqs = _requests(serve_env, 2)
    plan = FaultPlan([FaultSpec("serve.decode_fastpath", times=None)])
    with inject(plan):
        eng.run(reqs)
    rep = eng.last_report
    assert rep.ok and sorted(rep.completed) == [0, 1]
    assert all(len(r.generated) == 4 and not r.error for r in reqs)
    assert rep.decode_steps > 0
    assert rep.fastpath_errors == rep.decode_steps
    assert plan.fired("serve.decode_fastpath") == rep.decode_steps


def test_serve_wall_clock_deadline_on_injected_clock(serve_env):
    """deadline_s is measured on the engine's injectable clock: a
    FaultClock ticking 1s per decode step hits a 2.5s deadline after
    exactly 3 steps — deterministically, no ambient time."""
    from repro.serving import ServeEngine
    cfg, params = serve_env
    clk = FaultClock()
    eng = ServeEngine(params, cfg, batch_slots=2, max_len=64,
                      decode_fastpath=False, clock=clk)
    reqs = _requests(serve_env, 2, max_new=6)
    plan = FaultPlan([FaultSpec("serve.decode", kind="call",
                                fn=clk.ticker(1.0), times=None)])
    with inject(plan):
        eng.run(reqs, deadline_s=2.5)
    rep = eng.last_report
    assert rep.deadline_hit and not rep.ok
    assert rep.decode_steps == 3                 # t=3.0 >= 2.5 at loop top
    assert {f["phase"] for f in rep.failed} == {"deadline"}
    assert "wall-clock" in rep.failed[0]["error"]
    assert all(r.done for r in reqs)


# ---------------------------------------------------------------------------
# CI audit: every named hook point must have been VISITED by this suite
# (REPRO_FAULT_INJECTION=1 arms it; keep this test LAST in the file)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(os.environ.get("REPRO_FAULT_INJECTION") != "1",
                    reason="hook-audit runs in the CI fault-injection job")
def test_zz_fault_audit_every_hook_point_visited():
    missing = [h for h in HOOK_POINTS if not FAULT_AUDIT.get(h)]
    assert not missing, (f"hook points never visited: {missing} — an "
                         f"instrumented call site lost its fault_point()")
