"""Property-based tests (hypothesis) on system invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.dsl import language as tl
from repro.core.dsl.interp import interpret
from repro.core.lowering import transcompile

_SAFE_UNARY = ["tanh", "sigmoid", "softsign", "abs", "neg", "square",
               "sign", "relu", "hardsigmoid"]

_NP = {"tanh": np.tanh, "sigmoid": lambda v: 1 / (1 + np.exp(-v)),
       "softsign": lambda v: v / (1 + np.abs(v)), "abs": np.abs,
       "neg": lambda v: -v, "square": lambda v: v * v, "sign": np.sign,
       "relu": lambda v: np.maximum(v, 0),
       "hardsigmoid": lambda v: np.clip(v / 6 + 0.5, 0, 1)}


@settings(max_examples=12, deadline=None)
@given(
    numel=st.integers(min_value=9, max_value=3000),
    ops=st.lists(st.sampled_from(_SAFE_UNARY), min_size=1, max_size=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_random_chain_lowered_equals_numpy(numel, ops, seed):
    """For random op chains and awkward sizes, the transcompiled Pallas
    kernel must agree with numpy AND the DSL interpreter oracle."""
    from tests.core.test_transcompile import (build_elementwise_chain,
                                              _np_chain)
    shapes = {"input": (numel,), "output": (numel,)}
    prog = build_elementwise_chain(shapes, ops)
    art = transcompile(prog)
    x = np.random.RandomState(seed).randn(numel).astype(np.float32)
    got = np.asarray(art.module.make(shapes, interpret=True)(x))
    want = x.astype(np.float64)
    for op in ops:
        want = _NP[op](want)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


@settings(max_examples=30, deadline=None)
@given(
    numel=st.integers(min_value=1, max_value=10**9),
    max_tile=st.sampled_from([256, 1024, 4096]),
)
def test_host_plan_invariants(numel, max_tile):
    """Elementwise host planning: tiles cover the padded span exactly and
    the UB allocation stays within budget."""
    shapes = {"input": (numel,), "output": (numel,)}
    P = tl.ProgramBuilder("plan", task_shapes=shapes)
    h = P.host()
    n = h.numel("input")
    n_cores = h.let("n_cores", tl.NUM_CORES)
    tile = h.let("tile_length", tl.hmin(max_tile, tl.hcdiv(n, n_cores)))
    span = h.let("core_span", n_cores * tile)
    pn = h.let("padded_numel", tl.hcdiv(n, span) * span)
    per_core = h.let("per_core", pn // n_cores)
    n_tiles = h.let("n_tiles", per_core // tile)
    h.launch(grid="n_cores")
    v = h.values
    assert v["padded_numel"] >= numel
    assert v["padded_numel"] - numel < v["core_span"]
    assert v["n_tiles"] * v["tile_length"] * v["n_cores"] == v["padded_numel"]
    assert v["tile_length"] * 4 <= tl.VMEM_BUDGET


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_quantize_roundtrip_error_bound(data):
    from repro.distributed.compress import quantize, dequantize
    import jax.numpy as jnp
    shape = data.draw(st.sampled_from([(64,), (8, 32), (130,)]))
    scale = data.draw(st.floats(min_value=1e-3, max_value=1e3))
    x = np.random.RandomState(data.draw(
        st.integers(0, 2**31 - 1))).randn(*shape).astype(np.float32) * scale
    q, s = quantize(jnp.asarray(x))
    back = np.asarray(dequantize(q, s))
    # error bounded by half a quantization step
    assert np.max(np.abs(back - x)) <= float(s) * 0.5 + 1e-6


@settings(max_examples=10, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=300),
    cols=st.integers(min_value=3, max_value=700),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_rowwise_softmax_any_shape(rows, cols, seed):
    """The normalization expert example must stay correct for arbitrary
    (rows, cols), exercising Pass-4 padding and divisor block sizing."""
    from repro.core.planner import PLANNER_REGISTRY
    from repro.core.lowering.pipeline import Knobs
    from repro.core.task import KernelTask, TensorSpec
    from repro.core.dsl.ast import DType
    shapes = {"input": (rows, cols), "output": (rows, cols)}
    task = KernelTask(
        name="softmax", category="normalization", op="softmax",
        tensors=[TensorSpec("input", DType.f32, "in", 2),
                 TensorSpec("output", DType.f32, "out", 2)],
        shapes=shapes, check_shapes=shapes,
        ref=None, attrs={"pad_value": -3.0e38})
    prog = PLANNER_REGISTRY["softmax"](task, shapes, Knobs())
    art = transcompile(prog)
    x = np.random.RandomState(seed).randn(rows, cols).astype(np.float32)
    got = np.asarray(art.entry(x, interpret=True))
    e = np.exp(x - x.max(-1, keepdims=True))
    want = e / e.sum(-1, keepdims=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)
