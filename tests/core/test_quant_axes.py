"""Compositional axis product + quantized storage (DESIGN.md §17).

The search space is a product of registered program axes (variant ×
compute_dtype × storage_dtype), not a flat variant table: these tests pin
the migration contract (legacy tuned pointers / pure-f32 cache keys stay
byte-identical), the registry lifecycle (idempotent built-in registration,
``reset_registry``), the neighborhood structure (single-axis moves walk
the full product; dtype axes are per-task opt-in), the cache axis-safety
invariant (a tuned f32 artifact is never served for an int8 request), and
the acceptance bar: the tuner DISCOVERS int8-storage fused variants at
bandwidth-bound geometries and keeps f32 at compute-bound ones.
"""
import dataclasses
import json
import threading

import pytest

from repro.bench import suite
from repro.bench.tasks import fused_suite
from repro.core.fusion.chain import chain_storage_dtypes
from repro.core.lowering.pipeline import Knobs
from repro.core.planner import generate
from repro.core.resilience import (GuardedResolver, PersistentQuarantine,
                                   Quarantine, drain_events)
from repro.core.tuning import ArtifactCache, Candidate, neighbors, tune
from repro.core.tuning import space


@pytest.fixture(scope="module")
def tasks():
    return {t.name: t for t in suite()}


@pytest.fixture(scope="module")
def fused():
    return {t.name: t for t in fused_suite()}


def _pin_storage(task, dt, suffix=None):
    """A copy of ``task`` with the storage-dtype axis pinned via
    ``attrs['axes']`` (the planner applies it tuned or not)."""
    return dataclasses.replace(
        task, name=f"{task.name}_{suffix or dt}",
        attrs={**task.attrs, "axes": {"storage_dtype": dt}})


# ---------------------------------------------------------------------------
# Candidate schema migration
# ---------------------------------------------------------------------------

def test_candidate_from_dict_tolerates_schema_skew():
    """Legacy 4-field tuned pointers fill axis defaults; unknown future
    keys are dropped — both directions of skew round-trip."""
    legacy = {"variant": "rowreuse", "max_tile": 512, "pad": True,
              "backend": "explicit"}
    c = Candidate.from_dict(legacy)
    assert c.variant == "rowreuse" and c.max_tile == 512
    assert c.compute_dtype == "f32" and c.storage_dtype == "f32"
    assert c.dtype_axes() == {}

    future = {**dataclasses.asdict(Candidate()), "sparsity": "2:4"}
    assert Candidate.from_dict(future) == Candidate()

    q = Candidate.from_dict({"variant": "fused", "storage_dtype": "int8"})
    assert q.dtype_axes() == {"storage_dtype": "int8"}
    assert "storage_dtype=int8" in q.describe()


def test_legacy_tuned_pointer_consumed_without_research(tasks, tmp_path):
    """A pre-axis tuned pointer (4-field candidate dict, written by an
    older build) must be consumed as-is: no new search, axis defaults
    filled in."""
    from repro.core.codegen import emit
    cache = ArtifactCache(str(tmp_path))
    task = tasks["max_pool2d"]
    rec = {"candidate": {"variant": "rowreuse", "max_tile": 4096,
                         "pad": False, "backend": None},
           "ratio": 2.0, "codegen_version": emit.CODEGEN_VERSION}
    cache._tuned_path(task).write_text(json.dumps(rec))
    r = generate(task, tune=True, tune_budget=6, cache=cache)
    assert r.comp_ok and r.pass_ok
    assert r.tune is None, "legacy pointer must skip the search"
    assert r.artifact.program.name.endswith("_rowreuse")


# ---------------------------------------------------------------------------
# Registry lifecycle (idempotence / reset / thread-unambiguity)
# ---------------------------------------------------------------------------

def test_builtin_registration_idempotent_and_resettable():
    space._ensure_builtin_variants()
    snap_variants = {op: tuple(v) for op, v in
                     space.VARIANT_REGISTRY.items()}
    snap_storage = dict(space.STORAGE_DTYPES)
    for _ in range(3):
        space._ensure_builtin_variants()
    assert {op: tuple(v) for op, v in
            space.VARIANT_REGISTRY.items()} == snap_variants, \
        "repeat registration must not duplicate or reorder variants"
    assert dict(space.STORAGE_DTYPES) == snap_storage

    space.reset_registry()
    assert not space.VARIANT_REGISTRY and not space.STORAGE_DTYPES
    # any registry query re-arms the built-ins
    assert "rowreuse" in space.variants_for("avg_pool2d")
    assert {op: tuple(v) for op, v in
            space.VARIANT_REGISTRY.items()} == snap_variants
    assert dict(space.STORAGE_DTYPES) == snap_storage


def test_builtin_registration_thread_unambiguous():
    """Concurrent first callers must all observe the COMPLETED registry
    (double-checked lock), never a half-registered one."""
    space.reset_registry()
    barrier = threading.Barrier(8)
    results, errors = [], []

    def worker():
        try:
            barrier.wait()
            d = space.axis_domains("rmsnorm_swiglu")
            results.append((d["variant"], d["storage_dtype"]))
        except Exception as e:  # noqa: BLE001 — collected for the assert
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(set(results)) == 1, "threads observed different registries"
    variants, dtypes = results[0]
    assert "fused" in variants
    assert "int8" in dtypes and "fp8" in dtypes


def test_register_axis_rejects_duplicates_and_non_fields():
    with pytest.raises(ValueError):
        space.register_axis("storage_dtype", lambda op: ("f32",))
    with pytest.raises(ValueError):
        space.register_axis("not_a_candidate_field", lambda op: ("f32",))


def test_storage_axis_domains_follow_chain_eligibility():
    """The registered storage domain per op IS the chain's structural
    eligibility: flash_attention (everything matmul-adjacent) stays
    single-point, quantizable chains open int8+fp8."""
    assert space.storage_dtypes_for("rmsnorm_swiglu") == ("f32", "int8",
                                                          "fp8")
    assert space.storage_dtypes_for("attn_scores") == ("f32", "int8", "fp8")
    assert chain_storage_dtypes("flash_attention") == ()
    assert space.storage_dtypes_for("flash_attention") == ("f32",)
    # non-chain ops have a single-point storage domain
    assert space.storage_dtypes_for("relu") == ("f32",)


# ---------------------------------------------------------------------------
# Neighborhood structure: the product, one axis at a time
# ---------------------------------------------------------------------------

_AXIS_FIELDS = ("variant", "compute_dtype", "storage_dtype")


def _ndiff(a, b):
    return sum(getattr(a, f.name) != getattr(b, f.name)
               for f in dataclasses.fields(Candidate))


def test_neighbors_walk_the_full_axis_product():
    base = Candidate()
    op = "rmsnorm_swiglu"
    moves = neighbors(base, op)            # open_axes=None: all axes open
    assert moves == neighbors(base, op), "neighborhood must be deterministic"
    # every move flips exactly one candidate field
    assert all(_ndiff(base, c) == 1 for c in moves)
    # chain builders are knob_free: ONLY program-axis moves
    assert all(any(getattr(c, f) != getattr(base, f) for f in _AXIS_FIELDS)
               for c in moves)
    assert {c.variant for c in moves} >= {"fused"}
    assert {c.storage_dtype for c in moves} >= {"int8", "fp8"}
    # the product point (fused, int8) is reachable in two single-axis steps
    two_hop = {(c2.variant, c2.storage_dtype)
               for c in moves for c2 in neighbors(c, op)}
    assert ("fused", "int8") in two_hop and ("fused", "fp8") in two_hop
    # closure over repeated stepping covers the whole variant × storage
    # product (compute_dtype is single-point today)
    seen, frontier = {(base.variant, base.storage_dtype)}, [base]
    while frontier:
        nxt = []
        for c in frontier:
            for n in neighbors(c, op):
                key = (n.variant, n.storage_dtype)
                if key not in seen:
                    seen.add(key)
                    nxt.append(n)
        frontier = nxt
    want = {(v, d) for v in space.variants_for(op)
            for d in space.storage_dtypes_for(op)}
    assert seen == want, "climb cannot reach the full axis product"


def test_neighbors_dtype_axes_are_opt_in():
    """The dtype axes are gated by ``open_axes`` (the tuner passes
    ``task.attrs['tuner_axes']``): closed by default, variant always
    open — a numerics-changing axis never silently enters a search."""
    base = Candidate()
    closed = neighbors(base, "rmsnorm_swiglu", open_axes=())
    assert closed, "variant axis must stay open"
    assert all(c.storage_dtype == "f32" and c.compute_dtype == "f32"
               for c in closed)
    opened = neighbors(base, "rmsnorm_swiglu", open_axes=("storage_dtype",))
    assert {c.storage_dtype for c in opened} >= {"int8", "fp8"}
    # a pinned non-default assignment is preserved across variant moves
    pinned = Candidate(storage_dtype="int8")
    assert all(c.storage_dtype == "int8"
               for c in neighbors(pinned, "rmsnorm_swiglu", open_axes=())
               if c.variant != pinned.variant)


# ---------------------------------------------------------------------------
# Cache axis-safety: the fingerprint carries the full axis assignment
# ---------------------------------------------------------------------------

def test_cache_key_separates_axis_assignments(fused, tmp_path):
    cache = ArtifactCache(str(tmp_path))
    task = fused["bias_gelu"]
    k_f32 = cache.key_for(task, Knobs(), variant="fused")
    # pure-f32 keys are byte-identical to the pre-axis scheme: an empty
    # assignment must not perturb the digest (no mass invalidation)
    assert k_f32 == cache.key_for(task, Knobs(), variant="fused", axes={})
    assert k_f32 == cache.key_for(task, Knobs(), variant="fused", axes=None)
    k_i8 = cache.key_for(task, Knobs(), variant="fused",
                         axes={"storage_dtype": "int8"})
    k_f8 = cache.key_for(task, Knobs(), variant="fused",
                         axes={"storage_dtype": "fp8"})
    assert len({k_f32, k_i8, k_f8}) == 3, \
        "axis assignments must fingerprint separately"


def test_warmed_f32_cache_misses_for_int8_and_regenerates_clean(
        fused, tmp_path):
    """The end-to-end axis-safety story through the resilience ladder: a
    warmed f32 entry is NEVER served for an int8 request — the int8
    request regenerates on the top rung with ZERO degradation events and
    no quarantine traffic, and the f32 entry still hits afterwards."""
    cache = ArtifactCache(str(tmp_path))
    quar = PersistentQuarantine.from_cache(cache)
    base = fused["bias_gelu"]
    drain_events()

    resolver = GuardedResolver(cache, tune=True, tune_budget=2,
                               quarantine=quar)
    r32 = resolver.resolve(base)
    assert r32.rung == "cached_tuned" and not r32.events
    stores_after_f32 = cache.stores
    assert stores_after_f32 > 0

    r8 = resolver.resolve(_pin_storage(base, "int8"))
    assert r8.rung == "cached_tuned" and r8.verdict == "ok"
    assert not r8.events, "int8 regen must not descend the ladder"
    assert cache.stores > stores_after_f32, \
        "int8 request must regenerate, not be served the f32 artifact"
    assert not drain_events()

    # clean regeneration never touches the quarantine table — the
    # persistent file is not even created
    assert quar.entries() == {}
    assert not (cache.root / "quarantine.json").exists()
    # a restarted fleet member (fresh persistent table) resolves the
    # quantized task on the top rung, from cache, with no degradation
    quar2 = PersistentQuarantine.from_cache(cache)
    resolver2 = GuardedResolver(cache, tune=True, tune_budget=2,
                                quarantine=quar2)
    r8b = resolver2.resolve(_pin_storage(base, "int8"))
    assert r8b.rung == "cached_tuned" and not r8b.events
    assert r8b.result.cached, "second int8 resolve must hit its own entry"
    # and the original f32 entry is still intact
    r32b = resolver2.resolve(base)
    assert r32b.rung == "cached_tuned" and not r32b.events
    assert r32b.result.cached


def test_quarantined_f32_rung_does_not_block_int8_fingerprint(fused):
    """Quarantine is keyed by task fingerprint: poisoning the f32 task's
    top rungs must not impede the int8-pinned task (distinct
    fingerprint), and vice versa."""
    base = fused["bias_gelu"]
    int8 = _pin_storage(base, "int8")
    quar = Quarantine(threshold=1)
    fp32 = GuardedResolver._fingerprint(base)
    fp8_ = GuardedResolver._fingerprint(int8)
    assert fp32 != fp8_
    quar.note_failure(fp32, "regenerate")
    assert quar.blocked(fp32, "regenerate")
    assert not quar.blocked(fp8_, "regenerate")


# ---------------------------------------------------------------------------
# The acceptance bar: discovery, positive and negative
# ---------------------------------------------------------------------------

def test_tuner_discovers_int8_storage_at_bandwidth_bound_geometry(
        fused, tmp_path):
    """No hand-pinning: with the storage axis OPEN (attrs['tuner_axes']),
    the climb finds (variant=fused, storage_dtype=int8) on its own at the
    bandwidth-bound geometry, and it models strictly faster than the best
    f32 fused point (narrower HBM traffic is the entire win)."""
    task = fused["rmsnorm_swiglu_int8"]
    assert task.attrs.get("tuner_axes") == ("storage_dtype",)
    tr = tune(task, budget=8, cache=str(tmp_path))
    best = tr.best.candidate
    assert best.variant == "fused", tr.summary()
    assert best.storage_dtype == "int8", tr.summary()
    assert tr.best.ok
    f32_fused = [t for t in tr.trials if t.candidate.variant == "fused"
                 and t.candidate.storage_dtype == "f32" and t.ok]
    assert f32_fused, "the climb must have evaluated the f32 fused point"
    assert tr.best.ratio > max(t.ratio for t in f32_fused), \
        "int8 storage must model faster than f32 at this geometry"


def test_tuner_keeps_f32_at_compute_bound_small_geometry(fused, tmp_path):
    """The negative: at a small-column geometry the quantized lane pad
    (QLANE=512) inflates narrow tensors past their f32 footprint, so the
    tuner must keep the f32 fused variant — quantization is discovered
    only where it pays."""
    base = fused["rmsnorm_swiglu_int8"]
    small_shapes = {t: ((256, 96) if len(s) == 2 else (96,))
                    for t, s in base.shapes.items()}
    task = dataclasses.replace(base, name="rmsnorm_swiglu_small_q",
                               shapes=small_shapes)
    tr = tune(task, budget=8, cache=str(tmp_path))
    assert tr.best.candidate.variant == "fused", tr.summary()
    assert tr.best.candidate.storage_dtype == "f32", \
        f"tuner must not quantize a compute-bound geometry: {tr.summary()}"
