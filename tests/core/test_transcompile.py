"""Transcompiler end-to-end: both backends, oracle equivalence, feedback."""
import numpy as np
import pytest

from repro.core.dsl import ast as A
from repro.core.dsl import language as tl
from repro.core.dsl.interp import interpret
from repro.core.lowering import transcompile, generate_with_feedback, Knobs
from repro.core.lowering.pipeline import TranscompileError


def build_elementwise_chain(shapes, ops, pad=False):
    """Simple flat elementwise chain used across these tests."""
    from repro.core.examples.common import two_phase_build

    def core(shp):
        P = tl.ProgramBuilder("chain", category="test", task_shapes=shp)
        h = P.host()
        numel = h.numel("input")
        n_cores = h.let("n_cores", 8)
        tile = h.let("tile_length", tl.hmin(512, tl.hcdiv(numel, n_cores)))
        span = h.let("core_span", n_cores * tile)
        pn = h.let("padded_numel", tl.hcdiv(numel, span) * span)
        per_core = h.let("per_core", pn // n_cores)
        n_tiles = h.let("n_tiles", per_core // tile)
        h.launch(grid="n_cores")
        with P.kernel(tensors=[("input", tl.f32, "in", 1),
                               ("output", tl.f32, "out", 1)]):
            pid = tl.program_id(0)
            buf = tl.alloc_ub("buf", (tile,), tl.f32)
            with tl.for_range("t", 0, n_tiles) as t:
                off = pid * per_core + t * tile
                with tl.copyin():
                    tl.load("input", off, buf)
                with tl.compute():
                    for opname in ops:
                        getattr(tl, opname)(buf, buf)
                with tl.copyout():
                    tl.store("output", off, buf)
        return P.build()

    layout = {
        "input": {"flatten": True, "pad_multiple": "core_span",
                  "pad_value": 0.0},
        "output": {"flatten": True, "pad_multiple": "core_span",
                   "pad_value": 0.0},
    }
    return two_phase_build(core, shapes, layout)


def _np_chain(x, ops):
    fns = {"tanh": np.tanh, "exp": np.exp, "sigmoid":
           lambda v: 1 / (1 + np.exp(-v)), "square": lambda v: v * v,
           "abs": np.abs, "neg": lambda v: -v,
           "softsign": lambda v: v / (1 + np.abs(v))}
    y = x.astype(np.float64)
    for op in ops:
        y = fns[op](y)
    return y


@pytest.mark.parametrize("numel", [4096, 5000, 131])
def test_elementwise_chain_both_paths(numel):
    shapes = {"input": (numel,), "output": (numel,)}
    ops = ["tanh", "square", "softsign"]
    prog = build_elementwise_chain(shapes, ops)
    art = transcompile(prog)
    assert art.backend == "pipelined"
    fn = art.module.make(shapes, interpret=True)
    x = np.random.RandomState(0).randn(numel).astype(np.float32)
    out = np.asarray(fn(x))
    np.testing.assert_allclose(out, _np_chain(x, ops), rtol=1e-5, atol=1e-6)

    # explicit backend must agree with pipelined
    art2 = transcompile(prog, force_backend="explicit")
    out2 = np.asarray(art2.module.make(shapes, interpret=True)(x))
    np.testing.assert_allclose(out2, out, rtol=1e-6, atol=1e-7)


def test_lowered_matches_interpreter_oracle():
    numel = 2048
    shapes = {"input": (numel,), "output": (numel,)}
    prog = build_elementwise_chain(shapes, ["sigmoid", "neg"])
    art = transcompile(prog)
    x = np.random.RandomState(1).randn(numel).astype(np.float32)
    # interp runs on the PADDED task shapes the program was built with
    pshapes = prog.meta["task_shapes"]
    want = interpret(prog, {"input": x.reshape(pshapes["input"])},
                     {"output": pshapes["output"]})["output"]
    got = np.asarray(art.module.make(shapes, interpret=True)(x))
    np.testing.assert_allclose(got.reshape(-1), want.reshape(-1)[:numel],
                               rtol=1e-5, atol=1e-6)


def test_generated_source_is_readable_artifact():
    shapes = {"input": (1024,), "output": (1024,)}
    prog = build_elementwise_chain(shapes, ["exp"])
    art = transcompile(prog)
    src = art.source
    # the properties RQ3 relies on: header, host plan, staged structure
    assert "pl.pallas_call" in src
    assert "pl.BlockSpec" in src
    assert "def _plan(" in src
    assert "copyin" in src and "copyout" in src
    assert "rationale" in src or "#" in src
    compile(src, "<artifact>", "exec")   # syntactically valid standalone


def test_feedback_loop_budget_shrinks_tile():
    """A builder that over-allocates VMEM on the first attempt must be
    repaired by the tile-shrinking feedback (paper per-pass correction)."""
    calls = []

    def builder(knobs: Knobs):
        calls.append(knobs.max_tile)
        shapes = {"input": (1 << 14,), "output": (1 << 14,)}
        P = tl.ProgramBuilder("big", task_shapes=shapes)
        h = P.host()
        h.let("n_cores", 1)
        tile = h.let("tile_length", min(knobs.max_tile, 1 << 14))
        h.launch(grid="n_cores")
        with P.kernel(tensors=[("input", tl.f32, "in", 1),
                               ("output", tl.f32, "out", 1)]):
            # allocate WAY too many buffers at the requested tile
            bufs = [tl.alloc_ub(f"b{i}", (tile,), tl.f32)
                    for i in range(600)]
            with tl.copyin():
                tl.load("input", 0, bufs[0])
            with tl.compute():
                tl.copy(bufs[1], bufs[0])
            with tl.copyout():
                tl.store("output", 0, bufs[1])
        return P.build()

    art = generate_with_feedback(builder, Knobs(max_tile=16384))
    assert len(calls) > 1 and calls[-1] < calls[0]
    assert any("feedback" in line for line in art.pass_log)


def test_tque_tbuf_classification_logged():
    shapes = {"input": (1024,), "output": (1024,)}
    prog = build_elementwise_chain(shapes, ["tanh"])
    art = transcompile(prog)
    log = "\n".join(art.pass_log)
    assert "TQue(in)" in log and "TBuf" in log
