"""Jaxpr-level graph extraction (DESIGN.md §11): golden re-derivation of
every declared chain from traced model code, composite recognition,
barrier segmentation (dot_general / scan / dynamic_slice), masked-fill
canonicalization, barrier-cycle legality, naming/fingerprint stability and
determinism."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.fusion import (CHAINS, CHAIN_SOURCES, GRAPHS, OpGraph,
                               OpNode, ProposeError, chain_fingerprint,
                               extract_chains, extract_graph,
                               extracted_chains, propose_chains)
from repro.models.workloads import WORKLOADS

W = {w.name: w for w in WORKLOADS}


# ---------------------------------------------------------------------------
# Golden: extraction re-derives every declared fixture chain byte-identically
# ---------------------------------------------------------------------------

def test_extraction_rederives_all_declared_chains_byte_identical():
    """Every chain proposable from the hand-declared GRAPHS fixtures must
    also be derived by tracing the model workload library — and the
    registered CHAINS entry must be the fixture spec verbatim (stages,
    keep/route, pad values, tensor names), so planner registry entries,
    cache keys and kernels/generated/ artifacts cannot churn."""
    declared = {}
    for g in GRAPHS:
        for spec in propose_chains(g):
            declared[spec.name] = spec
    assert len(declared) == 6
    extracted_fps = {chain_fingerprint(s) for s, _ in extracted_chains()}
    for name, spec in declared.items():
        assert chain_fingerprint(spec) in extracted_fps, (
            f"extraction lost declared chain '{name}'")
        assert CHAINS[name] == spec, (
            f"registered '{name}' is not the declared fixture spec")
        assert CHAIN_SOURCES[name] == ("declared", "extracted")


def test_add_rmsnorm_extracted_from_real_ffn_block():
    """The add_rmsnorm chain comes out of the REAL pre-FFN segment
    (residual update + apply_norm flanked by the FFN matmuls), with the
    matmul barriers visible in the extracted graph and the escaping
    residual stream kept."""
    w = W["add_rmsnorm"]
    graph = extract_graph(w.fn, w.shapes, name=w.name)
    assert sum(n.op == "barrier.dot_general" for n in graph.nodes) == 3
    (spec,) = propose_chains(graph)
    assert [st.op for st in spec.stages] == ["add", "rmsnorm"]
    assert len(spec.keep) == 1                 # residual stream escapes
    declared = CHAINS["add_rmsnorm"]
    assert chain_fingerprint(spec) == chain_fingerprint(declared)


def test_barrier_cycle_does_not_swallow_post_ffn_residual_add():
    """The FFN output is added back onto the residual stream the chain
    itself produced: merging that add into the chain would make the fused
    kernel consume a tensor that only exists after it has run.  The
    proposer must stop the chain at {add, rmsnorm} — exactly one chain,
    two stages — instead of emitting a 3-stage unschedulable one."""
    w = W["add_rmsnorm"]
    specs = extract_chains(w.fn, w.shapes, name=w.name)
    assert len(specs) == 1
    assert len(specs[0].stages) == 2


# ---------------------------------------------------------------------------
# The NEW extracted chain: flash_attention THROUGH the matmul barriers
# ---------------------------------------------------------------------------

def test_flash_attention_extracted_through_matmul_barriers():
    """Tracing the real mha_reference yields ONE chain spanning both
    contractions: the qk^T and pv dot_generals classify as matmul stages
    (not barriers), where(causal, logits, -inf) is canonicalized into
    add(input, mask) and the softmax pattern collapses — the full
    flash-attention recipe derived from unmodified model code."""
    w = W["flash_attention"]
    graph = extract_graph(w.fn, w.shapes, name=w.name)
    ops = [n.op for n in graph.nodes]
    assert "barrier.dot_general" not in ops      # matmuls are now stages
    assert ops == ["matmul_t", "scale", "add", "softmax", "matmul"]
    assert "barrier.select_n" not in ops         # masked fill rewritten
    (spec,) = propose_chains(graph)
    assert [st.op for st in spec.stages] == [
        "matmul_t", "scale", "add", "softmax", "matmul"]
    # the traced qk scale (1/sqrt(head_dim)) rides the chain attrs
    assert abs(dict(spec.attrs)["scale"] - 0.25) < 1e-12


def test_flash_attention_registered_chain_structure():
    spec = CHAINS["flash_attention"]
    assert CHAIN_SOURCES["flash_attention"] == ("extracted",)
    assert spec.inputs == (("q", 2), ("k", 2), ("mask", 2), ("v", 2))
    assert spec.outputs == ("output",)
    assert [(st.op, st.inputs, st.output) for st in spec.stages] == [
        ("matmul_t", ("q", "k"), "h1"),
        ("scale", ("h1",), "h2"),
        ("add", ("h2", "mask"), "h3"),
        ("softmax", ("h3",), "h4"),
        ("matmul", ("h4", "v"), "output")]
    pads = dict(spec.pad_values)
    assert pads["mask"] == -3.0e38               # padded keys stay masked
    assert pads["h4"] == 0.0                     # padded probs contribute 0
    # q/k/v carry no explicit pad: the default zero-pad is matmul-neutral
    assert not {"q", "k", "v"} & set(pads)


def test_mask_softmax_registered_chain_structure():
    spec = CHAINS["mask_softmax"]
    assert CHAIN_SOURCES["mask_softmax"] == ("extracted",)
    assert spec.inputs == (("input", 2), ("mask", 2))
    assert spec.outputs == ("output",)
    assert [(st.op, st.inputs, st.output) for st in spec.stages] == [
        ("add", ("input", "mask"), "h"),
        ("softmax", ("h",), "output")]
    # neutral pad propagated backward through the mask add
    assert dict(spec.pad_values) == {"input": -3.0e38}


def test_mask_softmax_registered_end_to_end():
    """The extracted chain rides the full pipeline: planner default +
    streaming fallback, tuner variant, fused-suite task with the chain
    fingerprint in its cache attrs, checked-in generated artifact."""
    from repro.bench.tasks import fused_suite
    from repro.core.planner import PLANNER_REGISTRY
    from repro.core.tuning import variants_for
    assert "mask_softmax" in PLANNER_REGISTRY
    assert "mask_softmax_streaming" in PLANNER_REGISTRY
    assert "fused" in variants_for("mask_softmax")
    task = {t.name: t for t in fused_suite()}["mask_softmax"]
    assert task.attrs["chain_fingerprint"] == \
        chain_fingerprint(CHAINS["mask_softmax"])
    import repro.kernels.generated.mask_softmax as art
    assert callable(art.make)


def test_full_transformer_block_chains_all_dedupe():
    """The full pre-norm transformer layer is the end-to-end validation
    workload: everything fusable it contains must fingerprint-dedupe onto
    already-registered chains (the full flash_attention chain from the
    attention path — its scores segment no longer stops at the matmul
    barriers — and add_rmsnorm from the pre-FFN segment) — no accidental
    near-duplicate registrations."""
    w = W["transformer_block"]
    specs = extract_chains(w.fn, w.shapes, name=w.name)
    fps = sorted(chain_fingerprint(s) for s in specs)
    assert fps == sorted((chain_fingerprint(CHAINS["flash_attention"]),
                          chain_fingerprint(CHAINS["add_rmsnorm"])))


# ---------------------------------------------------------------------------
# Composite recognition units
# ---------------------------------------------------------------------------

def _single_chain(fn, shapes, name="unit"):
    specs = extract_chains(fn, shapes, name=name)
    assert len(specs) == 1, [s.name for s in specs]
    return specs[0]


@pytest.mark.parametrize("fn,ops", [
    (lambda x, b: jax.nn.gelu(x + b, approximate=True), ["add", "gelu"]),
    (lambda x, b: jax.nn.gelu(x + b, approximate=False), ["add", "gelu"]),
    (lambda x, b: jax.nn.silu(x + b), ["add", "silu"]),
    (lambda x, b: (lambda h: h * jax.nn.sigmoid(h))(x + b),
     ["add", "silu"]),
    (lambda x, b: jax.nn.relu(x + b), ["add", "relu"]),
    (lambda x, b: jnp.square(x + b), ["add", "square"]),
    (lambda x, b: jnp.tanh(x * b), ["mul", "tanh"]),
    (lambda x, b: jax.nn.silu(x + b) * x, ["add", "swiglu"]),
])
def test_composite_recognition(fn, ops):
    spec = _single_chain(fn, (("input", (4, 64)), ("bias", (64,))))
    assert [st.op for st in spec.stages] == ops


def test_rank3_model_tensors_canonicalize_to_rank2_chains():
    """(B, S, d) activations flatten to row tensors; trailing-broadcast
    weights stay rank-1 vectors."""
    from repro.models import layers as L
    from repro.models.workloads import _CFG
    spec = _single_chain(
        lambda x, w: jax.nn.silu(L.apply_norm({"scale": w}, x, _CFG)),
        (("input", (2, 8, 64)), ("weight", (64,))))
    assert spec.inputs == (("input", 2), ("weight", 1))
    assert [st.op for st in spec.stages] == ["rmsnorm", "silu"]


# ---------------------------------------------------------------------------
# Barrier segmentation: unsupported primitives segment, never mis-fuse
# ---------------------------------------------------------------------------

def test_dot_general_barrier_segments_extracted_graph():
    def fn(x, b, w, v):
        h = jax.nn.gelu(x + b)
        m = h @ w                       # matmul barrier
        return jnp.tanh(m * v)

    shapes = (("x", (8, 64)), ("b", (64,)), ("w", (64, 64)), ("v", (64,)))
    graph = extract_graph(fn, shapes, name="seg")
    assert any(n.op == "barrier.dot_general" for n in graph.nodes)
    first, second = propose_chains(graph)
    assert [st.op for st in first.stages] == ["add", "gelu"]
    assert [st.op for st in second.stages] == ["mul", "tanh"]
    # the matmul's output re-enters the downstream chain as a plain input
    barrier_out = next(n.output for n in graph.nodes
                       if n.op == "barrier.dot_general")
    assert second.inputs[0] == (barrier_out, 2)


def test_scan_barrier_segments_extracted_graph():
    def fn(x, b, v):
        h = jax.nn.silu(x + b)
        _, ys = jax.lax.scan(lambda c, row: (c + row, c + row),
                             jnp.zeros(x.shape[1]), h)
        return jnp.exp(ys * v)

    shapes = (("x", (8, 64)), ("b", (64,)), ("v", (64,)))
    graph = extract_graph(fn, shapes, name="seg_scan")
    assert any(n.op == "barrier.scan" for n in graph.nodes)
    specs = propose_chains(graph)
    assert [[st.op for st in s.stages] for s in specs] == [
        ["add", "silu"], ["mul", "exp"]]


def test_dynamic_slice_barrier_segments_extracted_graph():
    def fn(x, b, v):
        h = jax.nn.gelu(x + b)
        s = jax.lax.dynamic_slice(h, (0, 0), (4, x.shape[1]))
        return jnp.tanh(s * v)

    shapes = (("x", (8, 64)), ("b", (64,)), ("v", (64,)))
    graph = extract_graph(fn, shapes, name="seg_ds")
    assert any(n.op == "barrier.dynamic_slice" for n in graph.nodes)
    specs = propose_chains(graph)
    assert [[st.op for st in s.stages] for s in specs] == [
        ["add", "gelu"], ["mul", "tanh"]]


def test_barrier_nodes_carry_true_out_rank():
    """A reduction barrier's output is rank-1 — OpNode.out_rank must say
    so (inferring from the input would claim rank 2 and corrupt any
    downstream chain's primary-input rank check)."""
    graph = extract_graph(lambda x: jnp.sum(x, axis=-1) * 1.0,
                          (("x", (8, 64)),), name="red")
    red = next(n for n in graph.nodes if n.op == "barrier.reduce_sum")
    assert red.out_rank == 1


def test_pad_unsound_extraction_refuses_with_propose_error():
    """sigmoid -> softmax: no pad value survives sigmoid into softmax's
    neutral element, so the proposer must refuse the extracted chain
    rather than mis-fuse (same rule as declared graphs)."""
    with pytest.raises(ProposeError):
        extract_chains(lambda x: jax.nn.softmax(jax.nn.sigmoid(x), axis=-1),
                       (("x", (4, 64)),), name="bad")


# ---------------------------------------------------------------------------
# Masked-fill canonicalization gating
# ---------------------------------------------------------------------------

def test_masked_fill_only_rewrites_into_softmax():
    """where(pred, x, -inf) NOT consumed by a softmax keeps its select_n
    barrier — the additive-mask rewrite is only neutral under a softmax
    consumer."""
    def fn(x, m, b):
        return jnp.tanh(jnp.where(m > 0.0, x, -jnp.inf) + b)

    shapes = (("x", (4, 64)), ("m", (4, 64)), ("b", (64,)))
    graph = extract_graph(fn, shapes, name="nomask")
    assert any(n.op == "barrier.select_n" for n in graph.nodes)
    assert not any(t.startswith("%mask") for t, _ in graph.inputs)


def test_masked_fill_rewrite_synthesizes_mask_input():
    def fn(x, m):
        return jax.nn.softmax(jnp.where(m > 0.0, x, -jnp.inf), axis=-1)

    shapes = (("x", (4, 64)), ("m", (4, 64)))
    spec = _single_chain(fn, shapes, name="masked")
    assert [st.op for st in spec.stages] == ["add", "softmax"]
    assert ("mask", 2) in spec.inputs
    assert chain_fingerprint(spec) == \
        chain_fingerprint(CHAINS["mask_softmax"])


# ---------------------------------------------------------------------------
# Determinism and naming stability
# ---------------------------------------------------------------------------

def test_extraction_is_deterministic_across_runs():
    """Two full extraction sweeps produce identical specs in identical
    order — the precondition for the CI byte-determinism gate (which
    additionally re-runs extraction under two PYTHONHASHSEEDs)."""
    a = extracted_chains()
    b = extracted_chains()
    assert [(s.name, chain_fingerprint(s), s) for s, _ in a] == \
           [(s.name, chain_fingerprint(s), s) for s, _ in b]


def test_canonical_naming_is_stable_for_new_chains():
    """Chains with no declared fixture get deterministic canonical names:
    primary barrier-produced input -> 'input', synthesized mask -> 'mask',
    single link -> 'h', final observed output -> 'output'."""
    w = W["mask_softmax"]
    (spec,) = extract_chains(w.fn, w.shapes, name=w.name)
    assert spec.inputs == (("input", 2), ("mask", 2))
    assert spec.stages[0].output == "h"
    assert spec.outputs == ("output",)


def test_fingerprint_is_alpha_invariant_and_structure_sensitive():
    from repro.core.fusion import ChainSpec, ChainStage
    a = ChainSpec(name="a", inputs=(("x", 2), ("s", 1)),
                  outputs=("y",),
                  stages=(ChainStage("mul", ("x", "s"), "t"),
                          ChainStage("softmax", ("t",), "y")),
                  pad_values=(("x", -3.0e38), ("s", 1.0)))
    b = ChainSpec(name="b", inputs=(("input", 2), ("scale", 1)),
                  outputs=("output",),
                  stages=(ChainStage("mul", ("input", "scale"), "h"),
                          ChainStage("softmax", ("h",), "output")),
                  pad_values=(("input", -3.0e38), ("scale", 1.0)))
    assert chain_fingerprint(a) == chain_fingerprint(b)
    assert chain_fingerprint(a) == chain_fingerprint(CHAINS["mul_softmax"])
    c = ChainSpec(name="c", inputs=(("x", 2), ("s", 1)),
                  outputs=("y",),
                  stages=(ChainStage("add", ("x", "s"), "t"),
                          ChainStage("softmax", ("t",), "y")),
                  pad_values=(("x", -3.0e38),))
    assert chain_fingerprint(c) != chain_fingerprint(a)


# ---------------------------------------------------------------------------
# Non-default norm eps (DESIGN.md §12 satellite): the traced eps rides the
# composite's params into the chain attrs instead of hard-pinning 1e-6
# ---------------------------------------------------------------------------

def test_non_default_rmsnorm_eps_is_carried_not_barriered():
    """apply_norm with a non-default eps used to silently BARRIER the
    rmsnorm composite (the matcher hard-pinned eps == 1e-6).  Now any
    small eps matches and the traced value lands in the chain's attrs, so
    the recipe computes with the model's eps."""
    from repro.models import layers as L
    from repro.models.workloads import _CFG
    specs = extract_chains(
        lambda x, w: jax.nn.silu(L.apply_norm({"scale": w}, x, _CFG,
                                              eps=1e-5)),
        (("input", (4, 64)), ("weight", (64,))), name="eps_chain")
    assert len(specs) == 1
    assert [st.op for st in specs[0].stages] == ["rmsnorm", "silu"]
    eps = dict(specs[0].attrs)["eps"]
    assert abs(eps - 1e-5) < 1e-9

    # and the built chain USES it: differential vs the eps-aware oracle
    from repro.core.fusion import build_chain
    from repro.core.dsl.interp import interpret
    rows, cols = 4, 96
    shapes = {"input": (rows, cols), "weight": (cols,),
              "output": (rows, cols)}
    rng = np.random.RandomState(2)
    x = rng.randn(rows, cols).astype(np.float32)
    w = rng.uniform(0.5, 1.5, cols).astype(np.float32)
    x64, w64 = x.astype(np.float64), w.astype(np.float64)
    want = (x64 / np.sqrt((x64 * x64).mean(-1, keepdims=True) + eps)
            * w64) / (1 + np.exp(-(x64 / np.sqrt(
                (x64 * x64).mean(-1, keepdims=True) + eps) * w64)))
    prog = build_chain(specs[0], shapes, mode="fused", pattern="resident")
    xp = np.pad(x, [(0, 0), (0, 128 - cols)])
    wp = np.pad(w, [(0, 128 - cols)])
    got = interpret(prog, {"input": xp, "weight": wp},
                    {"output": (rows, 128)})["output"][:, :cols]
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=2e-5)


def test_default_eps_is_elided_from_attrs():
    """The recipe-default eps must NOT enter the chain attrs — otherwise
    every declared rmsnorm fixture would fingerprint apart from its
    extracted re-derivation."""
    from repro.models import layers as L
    from repro.models.workloads import _CFG
    specs = extract_chains(
        lambda x, w: jax.nn.silu(L.apply_norm({"scale": w}, x, _CFG)),
        (("input", (4, 64)), ("weight", (64,))), name="eps_default")
    assert dict(specs[0].attrs) == {}


def test_conflicting_eps_in_one_component_qualifies_per_stage():
    """Two norms with different eps in ONE fusable component used to refuse
    outright; the proposer now qualifies each value as ``eps@<stage out>``
    so both stages keep their own eps (needed for traced VJP chains whose
    stages legitimately disagree on scalar attrs)."""
    from repro.models import layers as L
    from repro.models.workloads import _CFG
    specs = extract_chains(
        lambda x, w, w2: L.apply_norm(
            {"scale": w2},
            L.apply_norm({"scale": w}, x, _CFG, eps=1e-4),
            _CFG, eps=2e-4),
        (("input", (4, 64)), ("w", (64,)), ("w2", (64,))),
        name="eps_conflict")
    assert len(specs) == 1
    spec = specs[0]
    assert [st.op for st in spec.stages] == ["rmsnorm", "rmsnorm"]
    attrs = dict(spec.attrs)
    assert attrs[f"eps@{spec.stages[0].output}"] == pytest.approx(1e-4)
    assert attrs[f"eps@{spec.stages[1].output}"] == pytest.approx(2e-4)


# ---------------------------------------------------------------------------
# log_softmax / layernorm composite coverage (formerly barrier.<prim>)
# ---------------------------------------------------------------------------

def test_log_softmax_composite_recognized():
    spec = _single_chain(lambda x, b: jax.nn.log_softmax(x + b, axis=-1),
                         (("input", (4, 64)), ("bias", (64,))))
    assert [st.op for st in spec.stages] == ["add", "log_softmax"]
    assert dict(spec.pad_values) == {"input": -3.0e38}


def test_layernorm_composite_recognized():
    from repro.models import layers as L
    from repro.models.workloads import _LN_CFG
    spec = _single_chain(
        lambda x, r, w, b: L.apply_norm({"scale": w, "bias": b}, x + r,
                                        _LN_CFG),
        (("input", (4, 64)), ("residual", (4, 64)), ("weight", (64,)),
         ("bias", (64,))))
    assert [st.op for st in spec.stages] == ["add", "layernorm"]
    assert spec.stages[1].inputs == ("h", "weight", "bias")
    # apply_norm's layernorm eps default (1e-6) differs from the recipe
    # default (1e-5): it must be carried
    assert abs(dict(spec.attrs)["eps"] - 1e-6) < 1e-9


def test_new_extraction_chains_registered_end_to_end():
    """double_softmax (multi-stat), bias_log_softmax and add_layernorm are
    extraction-only chains: registered, planner-wired, tuner-searchable,
    fused-suite-covered."""
    from repro.bench.tasks import fused_suite
    from repro.core.planner import PLANNER_REGISTRY
    from repro.core.tuning import variants_for
    tasks = {t.name for t in fused_suite()}
    for name in ("double_softmax", "bias_log_softmax", "add_layernorm"):
        assert name in CHAINS
        assert CHAIN_SOURCES[name] == ("extracted",)
        assert name in PLANNER_REGISTRY
        assert f"{name}_streaming" in PLANNER_REGISTRY
        assert "fused" in variants_for(name)
        assert name in tasks
    assert [st.op for st in CHAINS["double_softmax"].stages] == \
        ["softmax", "softmax"]
    assert dict(CHAINS["double_softmax"].pad_values) == {
        "input": -3.0e38, "h": -3.0e38}


def test_weightless_rmsnorm_composite_recognized_and_builds():
    """Gap fix (DESIGN.md §13 satellite): x * rsqrt(mean(x*x) + eps) with
    NO learned gain — the normalization idiom of gain-free norm layers —
    collapses to an arity-1 rmsnorm stage instead of barriering on the
    bare reduce, and the built chain computes the weightless recipe."""
    spec = _single_chain(
        lambda x: jax.nn.silu(
            x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True)
                              + 1e-6)),
        (("input", (4, 64)),), name="noweight_rmsnorm")
    assert [st.op for st in spec.stages] == ["rmsnorm", "silu"]
    assert [len(st.inputs) for st in spec.stages] == [1, 1]
    assert dict(spec.attrs) == {}            # default eps elided

    from repro.core.dsl.interp import interpret
    from repro.core.fusion import build_chain
    rows, cols = 4, 96
    rng = np.random.RandomState(3)
    x = rng.randn(rows, cols).astype(np.float32)
    x64 = x.astype(np.float64)
    h = x64 / np.sqrt((x64 * x64).mean(-1, keepdims=True) + 1e-6)
    want = h / (1 + np.exp(-h))
    prog = build_chain(spec, {"input": (rows, cols)}, mode="fused",
                       pattern="resident")
    xp = np.pad(x, [(0, 0), (0, 128 - cols)])
    got = interpret(prog, {"input": xp},
                    {"output": (rows, 128)})["output"][:, :cols]
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=2e-5)


def test_weightless_rmsnorm_non_default_eps_carried():
    """The traced eps of a weightless rmsnorm rides the chain attrs just
    like the weighted form's."""
    spec = _single_chain(
        lambda x: jax.nn.silu(
            x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True)
                              + 2e-5)),
        (("input", (4, 64)),), name="noweight_eps")
    assert [st.op for st in spec.stages] == ["rmsnorm", "silu"]
    eps = dict(spec.attrs)["eps"]
    assert abs(eps - 2e-5) < 1e-10          # f32-rounded trace constant


def test_decode_attention_extracts_and_dedupes_onto_flash():
    """The scan-free single-token decode block (KV-cache write + GQA
    attention over the cached keys, traced VERBATIM from
    layers.apply_attention's decode branch) yields ONE chain spanning both
    cache contractions.  The vmapped dynamic_update_slice cache writes and
    the QKV/rope/output projections stay barriers — the updated caches
    re-enter the attention interior as plain chain inputs — and the
    derived chain is structurally IDENTICAL to flash_attention: its
    α-invariant fingerprint dedupes onto the registered chain, so the
    decode path rides the same generated kernel with zero registry
    churn."""
    w = W["decode_attention"]
    specs = extract_chains(w.fn, w.shapes, name=w.name)
    assert len(specs) == 1
    (spec,) = specs
    assert [st.op for st in spec.stages] == [
        "matmul_t", "scale", "add", "softmax", "matmul"]
    # decode trace head_dim=16 → qk scale 1/sqrt(16)
    assert abs(dict(spec.attrs)["scale"] - 0.25) < 1e-12
    assert chain_fingerprint(spec) == \
        chain_fingerprint(CHAINS["flash_attention"])
    # dedupe: no separate registry entry, flash already carries the
    # "extracted" source tag
    assert "decode_attention" not in CHAINS
    assert "extracted" in CHAIN_SOURCES["flash_attention"]


def test_decode_attention_cache_ops_are_barriers_not_swallowed():
    """The cache write (dynamic_update_slice under vmap → scatter-style
    update) must segment the graph, not vanish into the chain: the fused
    decode kernel reads the UPDATED cache, which is producible only if the
    update runs as a barrier whose output feeds the chain."""
    w = W["decode_attention"]
    graph = extract_graph(w.fn, w.shapes, name=w.name)
    ops = [n.op for n in graph.nodes]
    # the vmapped dynamic_update_slice cache writes trace as scatters
    assert ops.count("barrier.scatter") == 2           # k and v writes
    # the four projections (wq/wk/wv/wo) are unbatched h @ w dots and
    # stay barriers; BOTH cache contractions classify as stages
    assert ops.count("barrier.dot_general") == 4
    assert ops.count("matmul_t") == 1 and ops.count("matmul") == 1


# ---------------------------------------------------------------------------
# Backward-path stop_gradient aliasing (DESIGN.md §16): remat'd VJPs
# ---------------------------------------------------------------------------

def test_checkpointed_norm_vjp_extracts_and_dedupes():
    """VJP of the pre-norm residual block under jax.checkpoint: the
    transposed jaxpr re-runs the forward with the saved residuals wrapped
    in stop_gradient (remat).  The extractor must alias straight through
    those wrappers on the backward path — same rule as forward — so the
    checkpointed trace yields the SAME [rmsnorm_bwd, add] chain and
    fingerprint-dedupes onto norm_residual_bwd instead of refusing."""
    w = W["ckpt_norm_bwd"]
    specs = extract_chains(w.fn, w.shapes, name=w.name)
    assert specs, "checkpointed VJP extraction refused (stop_gradient)"
    ops = [[st.op for st in s.stages] for s in specs]
    assert ["rmsnorm_bwd", "add"] in ops, ops
    (spec,) = [s for s in specs
               if [st.op for st in s.stages] == ["rmsnorm_bwd", "add"]]
    assert chain_fingerprint(spec) == \
        chain_fingerprint(CHAINS["norm_residual_bwd"])
    # dedupe means NO separate ckpt chain got registered
    assert not any(n.startswith("ckpt_norm") for n in CHAINS)
    assert CHAIN_SOURCES["norm_residual_bwd"] == ("extracted",)
