"""DSL builder / validator / interpreter unit tests."""
import numpy as np
import pytest

from repro.core.dsl import ast as A
from repro.core.dsl import language as tl
from repro.core.dsl import interpret, validate
from repro.core.dsl.validate import DSLValidationError


def build_scale(shapes, factor=2.0, bad_stage=False, oob=False):
    P = tl.ProgramBuilder("scale", category="test", task_shapes=shapes)
    h = P.host()
    numel = h.numel("input")
    n_cores = h.let("n_cores", 8)
    per_core = h.let("per_core", numel // n_cores)
    h.launch(grid="n_cores")
    with P.kernel(tensors=[("input", tl.f32, "in", 1),
                           ("output", tl.f32, "out", 1)]):
        pid = tl.program_id(0)
        buf = tl.alloc_ub("buf", (per_core,), tl.f32)
        off = pid * per_core + (per_core if oob else 0)
        with tl.copyin():
            tl.load("input", off, buf)
        with tl.compute():
            tl.mul(buf, buf, factor)
        with tl.copyout():
            tl.store("output", pid * per_core, buf)
    return P.build()


def test_build_and_interpret():
    shapes = {"input": (1024,), "output": (1024,)}
    prog = build_scale(shapes)
    rep = validate(prog)
    assert not rep.errors
    x = np.random.randn(1024).astype(np.float32)
    out = interpret(prog, {"input": x}, {"output": (1024,)})["output"]
    np.testing.assert_allclose(out, 2.0 * x, rtol=1e-6)


def test_stage_discipline_enforced_by_builder():
    shapes = {"input": (64,), "output": (64,)}
    P = tl.ProgramBuilder("bad", task_shapes=shapes)
    h = P.host()
    h.let("n_cores", 1)
    h.launch(grid="n_cores")
    with pytest.raises(tl.DSLBuildError):
        with P.kernel(tensors=[("input", tl.f32, "in", 1),
                               ("output", tl.f32, "out", 1)]):
            buf = tl.alloc_ub("b", (64,), tl.f32)
            tl.load("input", 0, buf)   # load outside copyin


def test_compute_op_outside_stage_rejected():
    shapes = {"input": (64,), "output": (64,)}
    P = tl.ProgramBuilder("bad2", task_shapes=shapes)
    h = P.host()
    h.let("n_cores", 1)
    h.launch(grid="n_cores")
    with pytest.raises(tl.DSLBuildError):
        with P.kernel(tensors=[("input", tl.f32, "in", 1),
                               ("output", tl.f32, "out", 1)]):
            buf = tl.alloc_ub("b", (64,), tl.f32)
            tl.exp(buf, buf)


def test_validator_oob_detected():
    shapes = {"input": (1024,), "output": (1024,)}
    prog = build_scale(shapes, oob=True)
    rep = validate(prog)
    assert any(d.code == "oob" for d in rep.errors)
    with pytest.raises(DSLValidationError):
        rep.raise_if_errors()


def test_validator_budget():
    shapes = {"input": (32 * 1024 * 1024,), "output": (32 * 1024 * 1024,)}
    prog = build_scale(shapes)   # per_core = 4M f32 = 16MB > budget
    rep = validate(prog)
    assert any(d.code == "budget" for d in rep.errors)


def test_validator_shape_mismatch():
    shapes = {"input": (64,), "output": (64,)}
    P = tl.ProgramBuilder("bad3", task_shapes=shapes)
    h = P.host()
    h.let("n_cores", 1)
    h.launch(grid="n_cores")
    with P.kernel(tensors=[("input", tl.f32, "in", 1),
                           ("output", tl.f32, "out", 1)]):
        a = tl.alloc_ub("a", (64,), tl.f32)
        b = tl.alloc_ub("b", (32,), tl.f32)
        with tl.copyin():
            tl.load("input", 0, a)
        with tl.compute():
            tl.add(b, a, a)          # dst shape mismatch
        with tl.copyout():
            tl.store("output", 0, a)
    rep = validate(P.build())
    assert any(d.code == "shape" for d in rep.errors)


def test_alloc_twice_rejected():
    shapes = {"input": (64,), "output": (64,)}
    P = tl.ProgramBuilder("bad4", task_shapes=shapes)
    h = P.host()
    h.let("n_cores", 1)
    h.launch(grid="n_cores")
    with pytest.raises(tl.DSLBuildError):
        with P.kernel(tensors=[("input", tl.f32, "in", 1),
                               ("output", tl.f32, "out", 1)]):
            tl.alloc_ub("a", (64,), tl.f32)
            tl.alloc_ub("a", (64,), tl.f32)


def test_interp_masked_load_pad_value():
    shapes = {"input": (100,), "output": (128,)}
    P = tl.ProgramBuilder("mask", task_shapes=shapes)
    h = P.host()
    h.let("n_cores", 1)
    h.launch(grid="n_cores")
    with P.kernel(tensors=[("input", tl.f32, "in", 1),
                           ("output", tl.f32, "out", 1)]):
        buf = tl.alloc_ub("b", (128,), tl.f32)
        with tl.copyin():
            tl.load("input", 0, buf, valid=100, pad_value=-1.0)
        with tl.compute():
            tl.copy(buf, buf)
        with tl.copyout():
            tl.store("output", 0, buf)
    prog = P.build()
    x = np.arange(100, dtype=np.float32)
    out = interpret(prog, {"input": x}, {"output": (128,)})["output"]
    np.testing.assert_allclose(out[:100], x)
    np.testing.assert_allclose(out[100:], -1.0)


def test_dsl_spec_document_complete():
    """The specification handed to generation front-ends lists every op."""
    from repro.core.dsl.spec import DSL_SPEC
    from repro.core.dsl import ast as A
    for op in A.UNARY_OPS + A.BINARY_OPS + A.REDUCE_OPS:
        assert op in DSL_SPEC, op
    for kw in ("copyin", "compute", "copyout", "alloc_ub", "VMEM_BUDGET",
               "rationale"):
        assert kw in DSL_SPEC, kw
