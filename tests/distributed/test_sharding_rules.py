"""Sharding-rule units that don't need multiple devices."""
import jax
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.distributed import sharding as S
from repro.models import transformer as T


def _fake_mesh_sizes():
    return {"data": 16, "model": 16}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_specs_divisible(arch):
    """Every sharded axis of every parameter must divide by its mesh axis
    size on the production mesh (16-way model)."""
    cfg = get_config(arch)
    aparams = jax.eval_shape(lambda k: T.init_params(k, cfg),
                             jax.random.PRNGKey(0))

    def check(path, arr):
        ps = S._path_str(path)
        spec = S.param_spec(ps, arr)
        for ax, dim in zip(spec, arr.shape):
            if ax == "model":
                # the shardings builder drops non-divisible axes; verify
                # the *common* projections do divide for real configs
                pass
        return None

    jax.tree_util.tree_map_with_path(check, aparams)
    # and the actual builder must produce valid NamedShardings on a real
    # (1,1) mesh without raising
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shardings = S.param_shardings(mesh, aparams)
    assert len(jax.tree.leaves(shardings)) == len(jax.tree.leaves(aparams))


def test_core_projections_model_sharded():
    cfg = get_config("qwen3-32b")
    aparams = jax.eval_shape(lambda k: T.init_params(k, cfg),
                             jax.random.PRNGKey(0))
    wq = aparams["body"]["l0"]["block"]["wq"]
    spec = S.param_spec("body/l0/block/wq", wq)
    assert tuple(spec) [: 3] == (None, None, "model")
    emb = aparams["embed"]
    assert tuple(S.param_spec("embed", emb))[0] == "model"


def test_moe_experts_ep_sharded():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    aparams = jax.eval_shape(lambda k: T.init_params(k, cfg),
                             jax.random.PRNGKey(0))
    w = aparams["body"]["l0"]["ffn"]["experts"]["w_gate"]
    spec = S.param_spec("body/l0/ffn/experts/w_gate", w)
    # stacked: (None, 'model', None, None) — experts over the model axis
    assert tuple(spec)[1] == "model"


def test_zero_sharding_prefers_largest_axis():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    arr = jax.ShapeDtypeStruct((64, 1024), np.float32)
    ns = S.zero_shardings(mesh, {"final_norm": {"scale": arr}})
    assert ns["final_norm"]["scale"] is not None
