"""Multi-device distribution tests.

These run in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(jax pins the device count at first init, so the main test process — which
must see 1 device for everything else — cannot host them).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.join(os.path.dirname(__file__), "..", "..")


def _run(code: str, timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\n" \
                                 f"STDERR:\n{proc.stderr[-4000:]}"
    return proc.stdout


def test_sharded_train_step_and_elastic_remesh(tmp_path):
    _run(f"""
        import jax, numpy as np, jax.numpy as jnp
        assert jax.device_count() == 8
        from repro.configs import get_config
        from repro.models import transformer as T
        from repro.training import optimizer as opt
        from repro.training.train import make_train_step
        from repro.distributed import sharding as S
        from repro.checkpoint import CheckpointManager

        cfg = get_config('internlm2-1.8b', smoke=True)
        ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=0)
        mesh42 = jax.make_mesh((4, 2), ('data', 'model'))
        mesh24 = jax.make_mesh((2, 4), ('data', 'model'))

        params = T.init_params(jax.random.PRNGKey(0), cfg)
        state = opt.init(params)
        batch = {{'tokens': jnp.ones((8, 32), jnp.int32)}}

        def run_on(mesh, params, state):
            ps = S.param_shardings(mesh, params)
            os_ = S.opt_state_shardings(mesh, state, params)
            bs = S.batch_shardings(mesh, batch)
            params = jax.device_put(params, ps)
            state = jax.device_put(state, os_)
            b = jax.device_put(batch, bs)
            step = jax.jit(make_train_step(cfg, ocfg),
                           in_shardings=(ps, os_, bs))
            return step(params, state, b)

        p1, s1, m1 = run_on(mesh42, params, state)
        assert np.isfinite(float(m1['loss']))

        # elastic remesh: checkpoint under (4,2), restore+step under (2,4)
        mgr = CheckpointManager({str(tmp_path)!r}, async_write=False)
        mgr.save(1, {{'params': p1, 'opt': s1}})
        like = {{'params': p1, 'opt': s1}}
        ps24 = S.param_shardings(mesh24, params)
        os24 = S.opt_state_shardings(mesh24, state, params)
        restored, _ = mgr.restore(1, like,
                                  shardings={{'params': ps24, 'opt': os24}})
        p2, s2, m2 = run_on(mesh24, restored['params'], restored['opt'])
        assert np.isfinite(float(m2['loss']))

        # same math on both meshes: one more step on mesh42 from p1
        p3, s3, m3 = run_on(mesh42, p1, s1)
        assert abs(float(m2['loss']) - float(m3['loss'])) < 1e-3
        print('elastic remesh OK', float(m2['loss']), float(m3['loss']))
    """)


def test_compressed_allreduce_and_pipeline():
    _run("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compress import make_compressed_allreduce
        from repro.distributed.pipeline import make_pipeline

        mesh = jax.make_mesh((8,), ('data',))
        rng = np.random.RandomState(0)
        local = jnp.asarray(rng.randn(8, 64, 32).astype(np.float32))
        err = jnp.zeros_like(local)
        fn = make_compressed_allreduce(mesh, {'g': local})
        out, new_err = fn({'g': local}, {'g': err})
        want = np.mean(np.asarray(local), axis=0)
        got = np.asarray(out['g'])[0]
        rel = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
        assert rel < 2e-2, rel
        # error feedback property: the *average* transmitted gradient over
        # rounds converges to the true mean (per-round error need not be
        # monotone)
        out2, _ = fn({'g': local}, new_err)
        got2 = np.asarray(out2['g'])[0]
        avg2 = (got + got2) / 2
        # L2 error of the running average roughly halves (compensation)
        assert np.linalg.norm(avg2 - want) <= \
            0.8 * np.linalg.norm(got - want)
        print('compressed allreduce OK', rel)

        # pipeline parallel: y = x @ W applied stage-by-stage == chained
        smesh = jax.make_mesh((8,), ('stage',))
        S, M, D = 8, 4, 16
        Ws = jnp.asarray(rng.randn(S, D, D).astype(np.float32) * 0.2)
        x = jnp.asarray(rng.randn(M, 4, D).astype(np.float32))

        def stage_fn(w, xb):
            return jnp.tanh(xb @ w)

        pipe = make_pipeline(smesh, stage_fn, Ws, n_micro=M)
        got = np.asarray(pipe(Ws, x))
        want = np.asarray(x)
        for s in range(S):
            want = np.tanh(want @ np.asarray(Ws[s]))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
        print('pipeline parallel OK')
    """)


def test_dryrun_single_cell_multipod():
    """End-to-end proof that the dry-run machinery works inside the test
    suite (512 fake devices in a subprocess; smallest arch).

    Was xfail (33.6 GB of involuntary-full-remat temps): fixed by (a)
    `sharding.constrain_activation` pinning the layer/scan boundary to the
    canonical batch×model layout (only when the batch axis carries the
    full DP degree — a partial pin measurably made it worse), and (b)
    computing the CE label pick as an equality-mask sum instead of
    `take_along_axis`, which gathered along the model-sharded vocab axis
    and forced XLA to replicate the full f32 logits.  Temps: 1.44 GB,
    zero involuntary remats."""
    _run("""
        import os
        os.environ['XLA_FLAGS'] = \
            '--xla_force_host_platform_device_count=512'
        import jax
        from repro.launch.mesh import make_production_mesh
        from repro.launch.steps import build_cell
        mesh = make_production_mesh(multi_pod=True)
        assert mesh.devices.size == 512
        fn, aargs, meta = build_cell('internlm2-1.8b', 'train_4k', mesh)
        with mesh:
            compiled = fn.lower(*aargs).compile()
            ma = compiled.memory_analysis()
        print('multi-pod compile OK; temp bytes/device =',
              ma.temp_size_in_bytes)
        assert ma.temp_size_in_bytes < 16e9   # fits v5e HBM
    """, timeout=560)
